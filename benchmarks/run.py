"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
  PYTHONPATH=src python -m benchmarks.run [--only query,ood,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("query", "pruning", "ood", "metrics", "construction", "updates",
          "hardware", "params", "stream", "adaptive", "serving",
          "robustness")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(SUITES))
    args, _ = ap.parse_known_args()
    chosen = [s for s in args.only.split(",") if s] or list(SUITES)
    print("name,us_per_call,derived")
    t_all = time.perf_counter()
    failures = []
    for suite in chosen:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["main"])
        t0 = time.perf_counter()
        try:
            mod.main()
            print(f"# suite {suite} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception as e:
            failures.append(suite)
            traceback.print_exc()
            print(f"# suite {suite} FAILED: {e}", flush=True)
    print(f"# total {time.perf_counter()-t_all:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
