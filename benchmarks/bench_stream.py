"""Streaming vs two-stage device engine: controlled N x D x d1 sweep.

Same fitted method, same queries, same facade entrypoint — the only variable
is ``SchedulePolicy.engine``.  Records QPS, recall, real survivor counts,
dimension pruning, and the peak estimate-tile footprint (the two-stage
engine materializes a (query_chunk, N) estimate matrix; the streaming engine
holds (query_chunk, row_block) + (query_chunk, block_capacity), independent
of N).  Writes BENCH_kernel.json at the repo root when run as a script.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import dataset, emit, fmt3, method_for
from repro.api import SchedulePolicy, SearchSession
from repro.vecdata.synthetic import recall_at_k

# (dataset, d1) cells: low-D, moderate-D, high-D, ultra-high-D corpora
SWEEP = (
    ("glove", 48), ("sift", 48), ("sift", 96),
    ("wikipedia", 128), ("openai", 128),
)
METHODS = ("PDScanning+", "DADE")
K, NQ, REPEATS = 10, 32, 5


def _policy(engine: str, d1: int) -> SchedulePolicy:
    return SchedulePolicy(d1=d1, query_chunk=32, capacity=2048, engine=engine)


def _run_cell(ds, name: str, d1: int, engine: str) -> dict:
    m = method_for(ds, name, k=K)
    sess = SearchSession(m, "flat", None, "jax", _policy(engine, d1))
    Q = ds.Q[:NQ]
    sess.search(Q, K)                       # compile + materialize
    best, res = np.inf, None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        r = sess.search(Q, K)
        dt = time.perf_counter() - t0
        if dt < best:
            best, res = dt, r
    gt, _ = ds.ground_truth(K)
    chunk = sess.policy.query_chunk
    est_bytes = (4 * chunk * ds.n if engine == "two_stage"
                 else 4 * chunk * (min(sess.policy.row_block, ds.n)
                                   + sess.policy.block_capacity))
    return {
        "dataset": ds.name, "n": ds.n, "dim": ds.dim, "d1": d1,
        "method": name, "engine": engine,
        "qps": NQ / best, "recall": recall_at_k(res.ids, gt[:NQ]),
        "pruning_ratio": res.stats.pruning_ratio,
        "survivors_mean": res.stats.extra.get("survivors_mean"),
        "uncertified_queries": res.stats.extra.get("uncertified_queries"),
        "estimate_tile_bytes": est_bytes,
    }


def main(json_path: str | None = None) -> dict:
    rows, ratios = [], []
    for ds_name, d1 in SWEEP:
        ds = dataset(ds_name)
        for name in METHODS:
            cell = {}
            for engine in ("two_stage", "stream"):
                cell[engine] = _run_cell(ds, name, d1, engine)
                rows.append(cell[engine])
            ratio = cell["stream"]["qps"] / cell["two_stage"]["qps"]
            ratios.append(ratio)
            emit(f"stream/{ds_name}/d1={d1}/{name}",
                 1e6 / cell["stream"]["qps"],
                 qps_stream=f"{cell['stream']['qps']:.1f}",
                 qps_two_stage=f"{cell['two_stage']['qps']:.1f}",
                 qps_ratio=fmt3(ratio),
                 recall_stream=fmt3(cell["stream"]["recall"]),
                 recall_two_stage=fmt3(cell["two_stage"]["recall"]),
                 est_bytes_stream=cell["stream"]["estimate_tile_bytes"],
                 est_bytes_two_stage=cell["two_stage"]["estimate_tile_bytes"])
    out = {
        "benchmark": "stream-vs-two-stage device engine (CPU jnp block path; "
                     "controlled: same method state, queries, facade)",
        "k": K, "nq": NQ, "repeats": REPEATS,
        "geomean_qps_ratio": float(np.exp(np.mean(np.log(ratios)))),
        "rows": rows,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    result = main("BENCH_kernel.json")
    print(f"# geomean qps ratio (stream / two_stage): "
          f"{result['geomean_qps_ratio']:.3f}")
