"""Streaming vs two-stage device engine: controlled N x D x d1 sweep.

Same fitted method, same queries, same facade entrypoint — the only variable
is the engine configuration: the legacy ``two_stage`` engine, the row-blocked
``stream`` engine, and ``pdx`` (the stream engine serving the PDX vertical
layout, ``dim_groups`` > 1 with per-group early exit; DESIGN.md §8).
Records QPS, recall, real survivor counts, dimension pruning, the measured
``dims_read_mean`` (dimensions actually touched per candidate — the direct
evidence of per-group early exit), and the peak estimate-tile footprint.
Writes BENCH_kernel.json at the repo root when run as a script; ``--dryrun``
is the CI smoke (tiny corpus, one cell per engine, no JSON).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import dataset, emit, fmt3, method_for
from repro.api import SchedulePolicy, SearchSession
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

# (dataset, d1) cells: low-D, moderate-D, high-D, ultra-high-D corpora
SWEEP = (
    ("glove", 48), ("sift", 48), ("sift", 96),
    ("wikipedia", 128), ("openai", 128),
)
METHODS = ("PDScanning+", "DADE")
#: engine cell -> SchedulePolicy overrides ("pdx" is the stream engine on the
#: dimension-grouped vertical layout)
ENGINES = {"two_stage": {"engine": "two_stage"},
           "stream": {"engine": "stream"},
           "pdx": {"engine": "stream", "dim_groups": 4}}
K, NQ, REPEATS = 10, 32, 5


def _policy(engine: str, d1: int) -> SchedulePolicy:
    return SchedulePolicy(d1=d1, query_chunk=32, capacity=2048,
                          **ENGINES[engine])


def _run_cell(ds, name: str, d1: int, engine: str, *, nq=NQ,
              repeats=REPEATS, k=K) -> dict:
    m = method_for(ds, name, k=k)
    sess = SearchSession(m, "flat", None, "jax", _policy(engine, d1))
    Q = ds.Q[:nq]
    sess.search(Q, k)                       # compile + materialize
    best, res = np.inf, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = sess.search(Q, k)
        dt = time.perf_counter() - t0
        if dt < best:
            best, res = dt, r
    gt, _ = ds.ground_truth(k)
    chunk = sess.policy.query_chunk
    est_bytes = (4 * chunk * ds.n if engine == "two_stage"
                 else 4 * chunk * (min(sess.policy.row_block, ds.n)
                                   + sess.policy.block_capacity))
    return {
        "dataset": ds.name, "n": ds.n, "dim": ds.dim, "d1": d1,
        "method": name, "engine": engine,
        "qps": nq / best, "recall": recall_at_k(res.ids, gt[:nq]),
        "pruning_ratio": res.stats.pruning_ratio,
        "survivors_mean": res.stats.extra.get("survivors_mean"),
        "uncertified_queries": res.stats.extra.get("uncertified_queries"),
        "dims_read_mean": res.stats.extra.get("dims_read_mean"),
        "estimate_tile_bytes": est_bytes,
    }


def main(json_path: str | None = None, *, dryrun: bool = False) -> dict:
    if dryrun:
        sweep, methods = ((("sift", 32),), ("PDScanning+",))
        ds_cache = {"sift": load_dataset("sift", scale=0.12)}   # ~1.2k x 128
        nq, repeats = 8, 1
    else:
        sweep, methods, ds_cache, nq, repeats = SWEEP, METHODS, {}, NQ, REPEATS
    rows, ratios, ratios_pdx = [], [], []
    for ds_name, d1 in sweep:
        ds = ds_cache.get(ds_name) or dataset(ds_name)
        for name in methods:
            cell = {}
            for engine in ENGINES:
                cell[engine] = _run_cell(ds, name, d1, engine,
                                         nq=nq, repeats=repeats)
                rows.append(cell[engine])
            ratio = cell["stream"]["qps"] / cell["two_stage"]["qps"]
            ratio_pdx = cell["pdx"]["qps"] / cell["stream"]["qps"]
            ratios.append(ratio)
            ratios_pdx.append(ratio_pdx)
            emit(f"stream/{ds_name}/d1={d1}/{name}",
                 1e6 / cell["stream"]["qps"],
                 qps_stream=f"{cell['stream']['qps']:.1f}",
                 qps_two_stage=f"{cell['two_stage']['qps']:.1f}",
                 qps_pdx=f"{cell['pdx']['qps']:.1f}",
                 qps_ratio=fmt3(ratio),
                 qps_ratio_pdx=fmt3(ratio_pdx),
                 recall_stream=fmt3(cell["stream"]["recall"]),
                 recall_pdx=fmt3(cell["pdx"]["recall"]),
                 dims_read_stream=fmt3(cell["stream"]["dims_read_mean"]),
                 dims_read_pdx=fmt3(cell["pdx"]["dims_read_mean"]),
                 est_bytes_stream=cell["stream"]["estimate_tile_bytes"],
                 est_bytes_two_stage=cell["two_stage"]["estimate_tile_bytes"])
    out = {
        "benchmark": "stream-vs-two-stage-vs-pdx device engine (CPU jnp "
                     "block path; controlled: same method state, queries, "
                     "facade)",
        "k": K, "nq": nq, "repeats": repeats,
        "geomean_qps_ratio": float(np.exp(np.mean(np.log(ratios)))),
        "geomean_qps_ratio_pdx_vs_stream":
            float(np.exp(np.mean(np.log(ratios_pdx)))),
        "rows": rows,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="CI smoke: tiny corpus, one cell per engine, no JSON")
    args = ap.parse_args()
    result = main(None if args.dryrun else "BENCH_kernel.json",
                  dryrun=args.dryrun)
    print(f"# geomean qps ratio (stream / two_stage): "
          f"{result['geomean_qps_ratio']:.3f}")
    print(f"# geomean qps ratio (pdx / stream): "
          f"{result['geomean_qps_ratio_pdx_vs_stream']:.3f}")
