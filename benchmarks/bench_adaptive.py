"""Adaptive DCO policy vs fixed rule vs fdscan under distribution shift.

The paper's OOD scenario (§V-B: multimodal query shift collapses pruning),
run through the facade's jax streaming engine on three query mixes per
dataset × method cell:

  id       in-distribution queries — screening should pay; adaptive must
           ride the fixed rule;
  ood      spectrum-shifted queries (``vecdata.make_ood_queries``, energy in
           the low-variance principal directions) — screening collapses; the
           fixed exact rule overflows its completion budget (uncertified),
           adaptive must degrade to certified fdscan;
  ood_mix  50/50, chunk-aligned — the production shape: adaptive screens the
           ID chunks and full-scans the OOD chunks in the same batch.

Controlled-pair convention: every cell compares the SAME fitted method
state, queries, and engine knobs; the competitor set for adaptive is
{fixed configured rule, fdscan} and a competitor must be *qualified* to win
— for exact rules that means certified exact (uncertified_queries == 0 and
recall 1.0: an uncertified answer cannot be served as exact in production),
for estimator rules recall within 0.005 of adaptive's.  Ratios are
adaptive_qps / best_qualified_qps; the headline acceptance number is the
geomean over the ``ood_mix`` cells (recorded per-mix so the pure-ood
insurance premium stays visible).  Writes BENCH_adaptive.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import dataset, emit, fmt3, method_for
from repro.api import SchedulePolicy, SearchSession
from repro.core.engine import (EXTRA_EST_SAVED_FLOPS, EXTRA_FALLBACK_BLOCKS,
                               EXTRA_UNCERTIFIED_QUERIES)
from repro.core.methods import make_method
from repro.vecdata.synthetic import make_ood_queries, recall_at_k

# (dataset, d1): geometries where screening pays on ID traffic (D >> d1)
SWEEP = (("laion", 64), ("wikipedia", 96))
METHODS = ("PDScanning+", "DADE")          # exact lower bound + estimator
K, NQ, REPEATS = 10, 128, 6
QUERY_CHUNK = 32                           # ood_mix is chunk-aligned 50/50
MARGIN = 1.5


def _sched(d1, **kw):
    return SchedulePolicy(d1=d1, query_chunk=QUERY_CHUNK, **kw)


def _mixes(ds):
    qid = ds.Q[:NQ]
    qood = make_ood_queries(ds.X, NQ, severity=1.0)
    return {"id": qid, "ood": qood,
            "ood_mix": np.concatenate([qid[:NQ // 2], qood[NQ // 2:]])}


def _gt(ds, Q):
    d2 = ((ds.X ** 2).sum(1)[None, :] - 2.0 * Q @ ds.X.T
          + (Q ** 2).sum(1)[:, None])
    row = np.arange(Q.shape[0])[:, None]
    idx = np.argpartition(d2, K - 1, axis=1)[:, :K]
    return idx[row, np.argsort(d2[row, idx], axis=1)]


def _measure(sessions, Q):
    """Interleaved best-of-REPEATS per session, in two rounds with the
    session order reversed (this container's 2-core timing noise is large
    and slowly drifting; alternation keeps the within-cell comparison
    fair)."""
    best = {name: np.inf for name in sessions}
    res = {}
    for name, s in sessions.items():
        s.search(Q, K)                                 # compile + warm
    order = list(sessions)
    for rnd in range(2):
        for _ in range(REPEATS // 2):
            for name in (order if rnd == 0 else order[::-1]):
                t0 = time.perf_counter()
                r = sessions[name].search(Q, K)
                dt = time.perf_counter() - t0
                if dt < best[name]:
                    best[name], res[name] = dt, r
    return {name: (len(Q) / best[name], res[name]) for name in sessions}


def main(json_path: str | None = None) -> dict:
    rows, ratios = [], {"id": [], "ood": [], "ood_mix": []}
    for ds_name, d1 in SWEEP:
        ds = dataset(ds_name)
        mixes = _mixes(ds)
        for name in METHODS:
            m = method_for(ds, name, k=K)
            exact_rule = name in ("PDScanning", "PDScanning+", "FDScanning")
            sessions = {
                "fixed": SearchSession(m, "flat", None, "jax", _sched(d1)),
                "fdscan": SearchSession(make_method("FDScanning").fit(ds.X),
                                        "flat", None, "jax", _sched(d1)),
                "adaptive": SearchSession(
                    m, "flat", None, "jax",
                    _sched(d1, adaptive=True, fallback_margin=MARGIN)),
            }
            for mix, Q in mixes.items():
                gt = _gt(ds, Q)
                out = _measure(sessions, Q)
                cell = {}
                for cname, (qps, r) in out.items():
                    cell[cname] = {
                        "qps": qps, "recall": recall_at_k(r.ids, gt),
                        "uncertified":
                            r.stats.extra.get(EXTRA_UNCERTIFIED_QUERIES),
                        "fallback_blocks":
                            r.stats.extra.get(EXTRA_FALLBACK_BLOCKS),
                        "est_saved_flops":
                            r.stats.extra.get(EXTRA_EST_SAVED_FLOPS),
                    }
                ad = cell["adaptive"]

                def qualified(c):
                    if exact_rule:
                        return c["recall"] >= 0.999 and not c["uncertified"]
                    return c["recall"] >= ad["recall"] - 0.005
                quals = {cn: cell[cn] for cn in ("fixed", "fdscan")
                         if qualified(cell[cn])}
                best_q = max(quals.values(), key=lambda c: c["qps"],
                             default=cell["fdscan"])
                ratio = ad["qps"] / best_q["qps"]
                if exact_rule:
                    # acceptance geomeans cover the exact-rule cells only:
                    # estimator rules keep recall through their capacity cut
                    # (the cut IS their speed and their certificate is
                    # advisory), so the exactness-first policy intentionally
                    # disagrees with them — reported, not gated
                    ratios[mix].append(ratio)
                rows.append({"dataset": ds_name, "n": ds.n, "dim": ds.dim,
                             "d1": d1, "method": name, "mix": mix,
                             "exact_rule": exact_rule,
                             "qualified_best_qps": best_q["qps"],
                             "ratio_vs_best": ratio, **{
                                 f"{cn}_{key}": v for cn, c in cell.items()
                                 for key, v in c.items()}})
                emit(f"adaptive/{ds_name}/{name}/{mix}",
                     1e6 / ad["qps"],
                     qps_adaptive=f"{ad['qps']:.1f}",
                     qps_fixed=f"{cell['fixed']['qps']:.1f}",
                     qps_fdscan=f"{cell['fdscan']['qps']:.1f}",
                     ratio_vs_best=fmt3(ratio),
                     recall_adaptive=fmt3(ad["recall"]),
                     recall_fixed=fmt3(cell["fixed"]["recall"]),
                     uncert_fixed=fmt3(cell["fixed"]["uncertified"] or 0.0),
                     fallback_blocks=f"{ad['fallback_blocks']:.1f}")

    def geo(v):
        return float(np.exp(np.mean(np.log(v)))) if v else float("nan")
    out = {
        "benchmark": "adaptive DCO policy vs {fixed rule, fdscan} under "
                     "query distribution shift (CPU jnp block path; "
                     "controlled: same fitted state, queries, engine knobs; "
                     "competitors must be qualified — certified exact for "
                     "exact rules — to be 'the better of')",
        "k": K, "nq": NQ, "repeats": REPEATS, "fallback_margin": MARGIN,
        "measurement_note":
            "2-vCPU container: identical compiled graphs measure with up to "
            "+-40% run-to-run wall-clock variance across processes; ratios "
            "are within-cell interleaved best-of-N and still inherit part "
            "of that noise.  In lean single-engine processes the adaptive "
            "engine's forced full-scan body measures 0.95-1.0x a dedicated "
            "fdscan session on pure-OOD batches; the ratios recorded here "
            "are what the shared container produced end-to-end.",
        "geomean_qps_ratio": {mix: geo(v) for mix, v in ratios.items()},
        "accept": {
            "ood_mix_geomean_ge_0.95":
                geo(ratios["ood_mix"]) >= 0.95,
            "exact_rule_recall_1.0_everywhere": all(
                r["adaptive_recall"] == 1.0 for r in rows
                if r["method"] in ("PDScanning+",)),
            "fallback_fired_on_every_ood_cell": all(
                r["adaptive_fallback_blocks"] > 0 for r in rows
                if r["mix"] != "id"),
        },
        "rows": rows,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    result = main("BENCH_adaptive.json")
    print("# geomean adaptive/best-qualified qps ratio: " + ", ".join(
        f"{mix}={v:.3f}" for mix, v in result["geomean_qps_ratio"].items()))
    print(f"# accept: {result['accept']}")
