"""App. H: initial step size Delta_0 and incremental step Delta_d study."""
from __future__ import annotations

from benchmarks.common import dataset, emit, fmt3, run_queries, session_for
from repro.api import SchedulePolicy

K = 10
METHODS = ("PDScanning", "PDScanning+", "ADSampling", "DADE", "DDCres")


def main():
    ds = dataset("gist")
    for delta0 in (16, 32, 64, 128):
        for name in METHODS:
            sess = session_for(ds, name, k=K,
                               policy=SchedulePolicy(delta0=delta0, delta_d=64))
            qps, rec, stats, us = run_queries(sess, ds, k=K, nq=10)
            emit(f"params_d0/gist/{name}/d0={delta0}", us,
                 qps=f"{qps:.1f}", recall=fmt3(rec),
                 prune=fmt3(stats.pruning_ratio))
    for delta_d in (32, 64, 160):
        for name in METHODS:
            sess = session_for(ds, name, k=K,
                               policy=SchedulePolicy(delta0=32, delta_d=delta_d))
            qps, rec, stats, us = run_queries(sess, ds, k=K, nq=10)
            emit(f"params_dd/gist/{name}/dd={delta_d}", us,
                 qps=f"{qps:.1f}", recall=fmt3(rec),
                 prune=fmt3(stats.pruning_ratio))


if __name__ == "__main__":
    main()
