"""Serving robustness under Poisson bursts at 1x/2x/4x capacity.

The §7 question: when offered load exceeds what the device can serve, does
the service degrade *predictably* — bounded queue, bounded accepted-request
tail latency, every ticket resolved — instead of collapsing into an
unbounded backlog?  And when a request's budget forces a partial scan, how
much of the corpus did it actually see and what recall did that buy?

Method: calibrate the full-batch service wall on a throwaway session, then
replay the SAME Poisson arrival sequence (discrete-event, measured walls —
the bench_serving pattern) at 1x, 2x, and 4x the calibrated capacity
against a bounded-queue ``SearchService`` with per-request deadlines.
Sheds, timeouts, partials, and failures are all legitimate outcomes; the
accounting invariant (``submitted == completed + shed + timeouts +
failures``) must hold exactly at every rate.

Per rate: shed/timeout/partial rates, the coverage distribution of served
requests (anytime scans report the scanned-block fraction), recall of
served requests vs the full-corpus oracle ("recall under deadline"), and
accepted-request p50/p95/p99.  The 4x acceptance: accepted p99 stays under
a structural bound derived from the queue depth (max wait ≈
ceil(max_queue/slots)+1 batches + own service), not from luck.

Writes BENCH_robustness.json; ``--dryrun`` is the CI smoke (tiny corpus,
one overloaded rate, slow-block fault injection to force deadline expiry
deterministically, no JSON).
"""
from __future__ import annotations

import argparse
import contextlib
import json

import numpy as np

from benchmarks.common import (dataset, emit, fmt3, latency_percentiles,
                               shared_pca)
from repro.api import SchedulePolicy, SearchSession
from repro.core.methods import make_method
from repro.testing import faults
from repro.vecdata import load_dataset

K, SLOTS = 10, 16
NQ_POOL = 64
MAX_QUEUE = 2 * SLOTS
RATES = (1.0, 2.0, 4.0)       # offered rate as a multiple of capacity
SEED = 23


def _build_session(X, pca, *, d1, row_block=4096, block_group=2):
    # anytime deadlines run the fixed streaming scan (the backend strips
    # the adaptive policy for deadline calls); a small block_group gives
    # the deadline mid-scan checkpoints even on a small corpus
    pol = SchedulePolicy(d1=d1, query_chunk=SLOTS, row_block=row_block,
                         anytime_block_group=block_group)
    m = make_method("PDScanning+", pca=pca).fit(X)
    return SearchSession(m, "flat", None, "jax", pol)


def _calibrate(svc, pool) -> float:
    """Steady full-batch service wall (seconds), after jit warm-up.

    Calibrated WITH a (generous) deadline so the measured wall is the
    grouped anytime scan the replay actually serves — the one-shot
    non-deadline path is faster (no per-group host syncs) and calibrating
    on it would make every replay rate an unintended overload."""
    for _ in range(2):
        for j in range(SLOTS):
            svc.submit(pool[j % len(pool)], deadline_s=1e3)
        svc.drain()
    steady = np.inf
    for _ in range(3):
        for j in range(SLOTS):
            svc.submit(pool[j % len(pool)], deadline_s=1e3)
        steady = min(steady, svc.step()[0].service_s)
        svc.drain()
    return steady


def _replay(svc, pool, qidx, arrivals):
    """Discrete-event replay: submit at the recorded arrival instants,
    serve with measured walls.  Returns every ticket, in submit order."""
    tickets, t, i = [], 0.0, 0
    while i < len(arrivals) or svc.pending:
        while i < len(arrivals) and arrivals[i] <= t:
            tickets.append(svc.submit(pool[qidx[i]], now=arrivals[i]))
            i += 1
        out = svc.step(now=t)
        if out:
            t = max(r.t_done for r in out)
        elif i < len(arrivals):
            t = max(t, arrivals[i])
        else:
            break
    svc.drain(now=t)
    return tickets


def _rate_row(sess, pool, qidx, arrivals, oracle, deadline_s, steady_s):
    svc = sess.serve(slots=SLOTS, k=K, nprobe=16, max_queue=MAX_QUEUE,
                     admission="shed_oldest", deadline_s=deadline_s)
    for j in range(SLOTS):                    # re-warm this service's jit on
        svc.submit(pool[j % len(pool)],       # the anytime path, full scan
                   deadline_s=1e3)
    svc.drain()
    warm = svc.health()
    tickets = _replay(svc, pool, qidx, arrivals)
    h = svc.health()
    done = [r for r in tickets if r.done]
    lat = [r.latency_s for r in done]
    cov = np.array([1.0 if r.coverage is None else r.coverage
                    for r in done], np.float64)
    recalls = [np.isin(r.ids[:K], oracle[qidx_of]).mean()
               for r, qidx_of in zip(tickets, qidx) if r.done]
    n = len(tickets)
    row = {
        "n_requests": n,
        "served": len(done),
        "shed_rate": (h["shed"] - warm["shed"]) / n,
        "timeout_rate": (h["timeouts"] - warm["timeouts"]) / n,
        "partial_rate": (sum(c < 1.0 for c in cov) / max(len(done), 1)),
        "failure_rate": (h["failures"] - warm["failures"]) / n,
        "coverage": {
            "mean": float(cov.mean()) if len(cov) else None,
            "min": float(cov.min()) if len(cov) else None,
            "p10": float(np.quantile(cov, 0.10)) if len(cov) else None,
        },
        "recall_under_deadline": float(np.mean(recalls)) if recalls else None,
        **(latency_percentiles(lat) if lat else
           {"p50_ms": None, "p95_ms": None, "p99_ms": None}),
        "accounting_exact": n == (len(done)
                                  + (h["shed"] - warm["shed"])
                                  + (h["timeouts"] - warm["timeouts"])
                                  + (h["failures"] - warm["failures"])),
        "p99_ewma_s": h["p99_ewma_s"],
    }
    # structural tail bound: a bounded queue admits at most MAX_QUEUE ahead
    # of you -> wait <= (ceil(MAX_QUEUE/SLOTS)+1) batches + own service;
    # 3x slack absorbs the container's service-wall noise
    row["p99_bound_ms"] = 3e3 * steady_s * (MAX_QUEUE / SLOTS + 2)
    row["p99_bounded"] = (row["p99_ms"] is not None
                          and row["p99_ms"] <= row["p99_bound_ms"])
    return row


def main(json_path: str | None = None, *, dryrun: bool = False) -> dict:
    if dryrun:
        ds = load_dataset("sift", scale=0.04)       # ~400 x 128
        n_req, d1, rates = 24, 32, (4.0,)
        build = dict(d1=d1, row_block=128, block_group=1)
        chaos = faults.inject(slow_block_s=0.002)   # force deadline expiry
    else:
        ds = dataset("sift")                        # 30k x 128
        n_req, d1, rates = 128, 64, RATES
        build = dict(d1=d1)
        chaos = contextlib.nullcontext()
    pca = shared_pca(ds)
    pool = np.ascontiguousarray(ds.Q[:NQ_POOL], np.float32)
    d2 = ((ds.X ** 2).sum(1)[None, :] - 2.0 * pool @ ds.X.T
          + (pool ** 2).sum(1)[:, None])
    row_idx = np.arange(pool.shape[0])[:, None]
    part = np.argpartition(d2, K - 1, axis=1)[:, :K]
    oracle = part[row_idx, np.argsort(d2[row_idx, part], axis=1)]

    sess0 = _build_session(ds.X, pca, **build)
    steady_s = _calibrate(sess0.serve(slots=SLOTS, k=K), pool)
    del sess0
    capacity_qps = SLOTS / steady_s
    # budget ~ a short queue's worth of service; binds only under overload
    deadline_s = 4.0 * steady_s
    rng = np.random.default_rng(SEED)
    qidx = [int(i % NQ_POOL) for i in range(n_req)]

    rows = {}
    sess = _build_session(ds.X, pca, **build)
    with chaos:
        for rate in rates:
            lam = rate * capacity_qps
            arrivals = np.cumsum(rng.exponential(1.0 / lam, n_req))
            row = _rate_row(sess, pool, qidx, arrivals, oracle,
                            deadline_s, steady_s)
            row["offered_qps"] = lam
            rows[f"{rate:g}x"] = row
            emit(f"robustness/{ds.name}/{rate:g}x",
                 0.0 if row["p50_ms"] is None else 1e3 * row["p50_ms"],
                 p99_ms="-" if row["p99_ms"] is None
                 else f"{row['p99_ms']:.1f}",
                 shed=fmt3(row["shed_rate"]),
                 timeout=fmt3(row["timeout_rate"]),
                 partial=fmt3(row["partial_rate"]),
                 cov="-" if row["coverage"]["mean"] is None
                 else fmt3(row["coverage"]["mean"]),
                 recall="-" if row["recall_under_deadline"] is None
                 else fmt3(row["recall_under_deadline"]),
                 ok=row["accounting_exact"])

    overload = rows[f"{max(rates):g}x"]
    out = {
        "benchmark": "serving robustness under Poisson bursts at multiples "
                     "of calibrated capacity (bounded queue, per-request "
                     "deadlines, anytime partial results; discrete-event "
                     "replay of measured service walls)",
        "dataset": {"name": ds.name, "n": ds.n, "dim": ds.dim},
        "k": K, "slots": SLOTS, "d1": d1, "max_queue": MAX_QUEUE,
        "admission": "shed_oldest",
        "calibration": {"steady_step_ms": 1e3 * steady_s,
                        "capacity_qps": capacity_qps,
                        "deadline_ms": 1e3 * deadline_s},
        "measurement_note":
            "2-vCPU container: service walls inherit up to +-40% "
            "run-to-run noise; rates are paired against one calibration "
            "so the shed/timeout/coverage ORDERING across 1x/2x/4x is the "
            "signal, absolute walls are not.",
        "accept": {
            "accounting_exact_all_rates": all(
                r["accounting_exact"] for r in rows.values()),
            "overload_p99_bounded": bool(overload["p99_bounded"]),
            "overload_sheds_or_times_out": (
                overload["shed_rate"] + overload["timeout_rate"] > 0.0),
            # a partial scan is exact over its prefix, so on shuffled data
            # recall tracks coverage; 0.5x slack absorbs query skew
            "recall_tracks_coverage": all(
                r["recall_under_deadline"] is None
                or r["coverage"]["mean"] is None
                or r["recall_under_deadline"] >= 0.5 * r["coverage"]["mean"]
                for r in rows.values()),
        },
        "rates": rows,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny corpus, 4x only, injected slow blocks, "
                         "no JSON (CI smoke)")
    args = ap.parse_args()
    if args.dryrun:
        result = main(dryrun=True)
    else:
        result = main("BENCH_robustness.json")
    print(f"# accept: {result['accept']}")
