"""Serving robustness: Poisson overload (§7) and query-drift guardrails (§9).

Two suites over the same serving stack, selected by ``--scenario``:

**overload** — when offered load exceeds what the device can serve, does
the service degrade *predictably* — bounded queue, bounded accepted-request
tail latency, every ticket resolved — instead of collapsing into an
unbounded backlog?  Calibrate the full-batch service wall on a throwaway
session, then replay the SAME Poisson arrival sequence (discrete-event,
measured walls — the bench_serving pattern) at 1x, 2x, and 4x the
calibrated capacity against a bounded-queue ``SearchService`` with
per-request deadlines.  Sheds, timeouts, partials, and failures are all
legitimate outcomes; the accounting invariant (``submitted == completed +
shed + timeouts + failures``) must hold exactly at every rate, and
accepted p99 must stay under a structural queue-depth bound.

**drift** — does the guardrail layer (DESIGN.md §9) catch query drift and
bound the damage?  Four cells over a guarded PDScanning+ session: a
no-drift *control* (breaker must stay closed; audit overhead vs an
unguarded twin must stay <= 5% wall at the 1/64 sampling rate) and the
three ``vecdata.make_drift_scenario`` profiles (*gradual* / *sudden* /
*recovering*).  Per cell, every batch's served breaker state, drift score,
and brute-force recall are recorded.  Acceptance: the sudden shift opens
the breaker within 8 batches; every batch served while the breaker is
open/half-open (the certified full scan) has recall 1.000; the recovering
cell re-promotes through half-open canaries; request accounting is exact
in every cell.

**failover** — does the replicated tier (DESIGN.md §10) survive losing
and regaining a replica mid-stream?  Two cells.  *kill_revive*: a
shard-mode ``ReplicatedService`` replays a Poisson arrival sequence while
one shard is killed a third of the way in (``faults.install``) and
revived at two thirds; zero acknowledged tickets may be lost, the
accounting invariant must hold exactly, every answer served during the
outage must be flagged (coverage < 1, certificate withdrawn, ``degraded``)
with recall honest against its coverage, and the revived shard must
re-admit through half-open probes and restore full-coverage certified
answers.  *hedge*: a replicate-mode tier with one injected straggler
replica serves the SAME Poisson arrivals twice — hedging armed vs
disarmed — on a deterministic injected timer; hedged p99 must beat the
unhedged control.

Writes BENCH_robustness.json; ``--dryrun`` is the CI smoke (tiny corpus,
one overloaded rate / the sudden drift cell only / shortened failover
replay, fault injection for determinism, no JSON, hard RuntimeError on a
failed drift or failover acceptance).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json

import numpy as np

from benchmarks.common import (dataset, emit, fmt3, latency_percentiles,
                               shared_pca)
from repro.api import GuardrailConfig, SchedulePolicy, SearchSession
from repro.core.engine import EXTRA_DEGRADED
from repro.core.methods import make_method
from repro.serving import ReplicaPolicy, open_replicated
from repro.testing import FaultPlan, faults
from repro.vecdata import load_dataset, make_drift_scenario, make_ood_queries

K, SLOTS = 10, 16
NQ_POOL = 64
MAX_QUEUE = 2 * SLOTS
RATES = (1.0, 2.0, 4.0)       # offered rate as a multiple of capacity
SEED = 23
SCENARIOS = ("overload", "drift", "failover", "all")


def _build_session(X, pca, *, d1, row_block=4096, block_group=2,
                   guardrails=None, block_capacity=128):
    # anytime deadlines run the fixed streaming scan (the backend strips
    # the adaptive policy for deadline calls); a small block_group gives
    # the deadline mid-scan checkpoints even on a small corpus
    pol = SchedulePolicy(d1=d1, query_chunk=SLOTS, row_block=row_block,
                         anytime_block_group=block_group,
                         block_capacity=block_capacity,
                         guardrails=guardrails)
    m = make_method("PDScanning+", pca=pca).fit(X)
    return SearchSession(m, "flat", None, "jax", pol)


def _calibrate(svc, pool) -> float:
    """Steady full-batch service wall (seconds), after jit warm-up.

    Calibrated WITH a (generous) deadline so the measured wall is the
    grouped anytime scan the replay actually serves — the one-shot
    non-deadline path is faster (no per-group host syncs) and calibrating
    on it would make every replay rate an unintended overload."""
    for _ in range(2):
        for j in range(SLOTS):
            svc.submit(pool[j % len(pool)], deadline_s=1e3)
        svc.drain()
    steady = np.inf
    for _ in range(3):
        for j in range(SLOTS):
            svc.submit(pool[j % len(pool)], deadline_s=1e3)
        steady = min(steady, svc.step()[0].service_s)
        svc.drain()
    return steady


def _replay(svc, pool, qidx, arrivals):
    """Discrete-event replay: submit at the recorded arrival instants,
    serve with measured walls.  Returns every ticket, in submit order."""
    tickets, t, i = [], 0.0, 0
    while i < len(arrivals) or svc.pending:
        while i < len(arrivals) and arrivals[i] <= t:
            tickets.append(svc.submit(pool[qidx[i]], now=arrivals[i]))
            i += 1
        out = svc.step(now=t)
        if out:
            t = max(r.t_done for r in out)
        elif i < len(arrivals):
            t = max(t, arrivals[i])
        else:
            break
    svc.drain(now=t)
    return tickets


def _rate_row(sess, pool, qidx, arrivals, oracle, deadline_s, steady_s):
    svc = sess.serve(slots=SLOTS, k=K, nprobe=16, max_queue=MAX_QUEUE,
                     admission="shed_oldest", deadline_s=deadline_s)
    for j in range(SLOTS):                    # re-warm this service's jit on
        svc.submit(pool[j % len(pool)],       # the anytime path, full scan
                   deadline_s=1e3)
    svc.drain()
    warm = svc.health()
    tickets = _replay(svc, pool, qidx, arrivals)
    h = svc.health()
    done = [r for r in tickets if r.done]
    lat = [r.latency_s for r in done]
    cov = np.array([1.0 if r.coverage is None else r.coverage
                    for r in done], np.float64)
    recalls = [np.isin(r.ids[:K], oracle[qidx_of]).mean()
               for r, qidx_of in zip(tickets, qidx) if r.done]
    n = len(tickets)
    row = {
        "n_requests": n,
        "served": len(done),
        "shed_rate": (h["shed"] - warm["shed"]) / n,
        "timeout_rate": (h["timeouts"] - warm["timeouts"]) / n,
        "partial_rate": (sum(c < 1.0 for c in cov) / max(len(done), 1)),
        "failure_rate": (h["failures"] - warm["failures"]) / n,
        "coverage": {
            "mean": float(cov.mean()) if len(cov) else None,
            "min": float(cov.min()) if len(cov) else None,
            "p10": float(np.quantile(cov, 0.10)) if len(cov) else None,
        },
        "recall_under_deadline": float(np.mean(recalls)) if recalls else None,
        **(latency_percentiles(lat) if lat else
           {"p50_ms": None, "p95_ms": None, "p99_ms": None}),
        "accounting_exact": n == (len(done)
                                  + (h["shed"] - warm["shed"])
                                  + (h["timeouts"] - warm["timeouts"])
                                  + (h["failures"] - warm["failures"])),
        "p99_ewma_s": h["p99_ewma_s"],
    }
    # structural tail bound: a bounded queue admits at most MAX_QUEUE ahead
    # of you -> wait <= (ceil(MAX_QUEUE/SLOTS)+1) batches + own service;
    # 3x slack absorbs the container's service-wall noise
    row["p99_bound_ms"] = 3e3 * steady_s * (MAX_QUEUE / SLOTS + 2)
    row["p99_bounded"] = (row["p99_ms"] is not None
                          and row["p99_ms"] <= row["p99_bound_ms"])
    return row


def _overload_suite(ds, pca, *, dryrun: bool) -> dict:
    """Poisson bursts at multiples of calibrated capacity (§7)."""
    if dryrun:
        n_req, d1, rates = 24, 32, (4.0,)
        build = dict(d1=d1, row_block=128, block_group=1)
        chaos = faults.inject(slow_block_s=0.002)   # force deadline expiry
    else:
        n_req, d1, rates = 128, 64, RATES
        build = dict(d1=d1)
        chaos = contextlib.nullcontext()
    pool = np.ascontiguousarray(ds.Q[:NQ_POOL], np.float32)
    oracle = _oracle(ds.X, pool)

    sess0 = _build_session(ds.X, pca, **build)
    steady_s = _calibrate(sess0.serve(slots=SLOTS, k=K), pool)
    del sess0
    capacity_qps = SLOTS / steady_s
    # budget ~ a short queue's worth of service; binds only under overload
    deadline_s = 4.0 * steady_s
    rng = np.random.default_rng(SEED)
    qidx = [int(i % NQ_POOL) for i in range(n_req)]

    rows = {}
    sess = _build_session(ds.X, pca, **build)
    with chaos:
        for rate in rates:
            lam = rate * capacity_qps
            arrivals = np.cumsum(rng.exponential(1.0 / lam, n_req))
            row = _rate_row(sess, pool, qidx, arrivals, oracle,
                            deadline_s, steady_s)
            row["offered_qps"] = lam
            rows[f"{rate:g}x"] = row
            emit(f"robustness/{ds.name}/{rate:g}x",
                 0.0 if row["p50_ms"] is None else 1e3 * row["p50_ms"],
                 p99_ms="-" if row["p99_ms"] is None
                 else f"{row['p99_ms']:.1f}",
                 shed=fmt3(row["shed_rate"]),
                 timeout=fmt3(row["timeout_rate"]),
                 partial=fmt3(row["partial_rate"]),
                 cov="-" if row["coverage"]["mean"] is None
                 else fmt3(row["coverage"]["mean"]),
                 recall="-" if row["recall_under_deadline"] is None
                 else fmt3(row["recall_under_deadline"]),
                 ok=row["accounting_exact"])

    overload = rows[f"{max(rates):g}x"]
    return {
        "d1": d1,
        "calibration": {"steady_step_ms": 1e3 * steady_s,
                        "capacity_qps": capacity_qps,
                        "deadline_ms": 1e3 * deadline_s},
        "accept": {
            "accounting_exact_all_rates": all(
                r["accounting_exact"] for r in rows.values()),
            "overload_p99_bounded": bool(overload["p99_bounded"]),
            "overload_sheds_or_times_out": (
                overload["shed_rate"] + overload["timeout_rate"] > 0.0),
            # a partial scan is exact over its prefix, so on shuffled data
            # recall tracks coverage; 0.5x slack absorbs query skew
            "recall_tracks_coverage": all(
                r["recall_under_deadline"] is None
                or r["coverage"]["mean"] is None
                or r["recall_under_deadline"] >= 0.5 * r["coverage"]["mean"]
                for r in rows.values()),
        },
        "rates": rows,
    }


# ---------------------------------------------------------------------------
# drift suite (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _oracle(X, Q) -> np.ndarray:
    """Exact top-K ids by brute force, per query batch."""
    d2 = ((X ** 2).sum(1)[None, :] - 2.0 * Q @ X.T + (Q ** 2).sum(1)[:, None])
    row = np.arange(Q.shape[0])[:, None]
    part = np.argpartition(d2, K - 1, axis=1)[:, :K]
    return part[row, np.argsort(d2[row, part], axis=1)]


def _serve_batch(svc, Q, oracle):
    """Submit one batch, serve one step, return (recall, breaker stats)."""
    tickets = [svc.submit(q) for q in Q]
    svc.step()
    rec = float(np.mean([np.isin(r.ids[:K], oracle[j]).mean()
                         for j, r in enumerate(tickets)]))
    st = tickets[0].stats
    return rec, st


def _drift_cell(ds, pca, gcfg, scenario: str, n_batches: int, *,
                build: dict, severity: float = 1.0) -> dict:
    """One guarded serving run over a ``make_drift_scenario`` stream."""
    sess = _build_session(ds.X, pca, guardrails=gcfg, **build)
    svc = sess.serve(slots=SLOTS, k=K)
    g = sess.backend.guardrail
    # warm both jitted paths (screened + demoted/certified) so compile
    # walls don't masquerade as serving behavior, then reset the breaker
    warm = np.ascontiguousarray(ds.Q[:SLOTS], np.float32)
    for _ in range(2):
        for q in warm:
            svc.submit(q)
        svc.drain()
    g.force_state("open")
    for q in warm:
        svc.submit(q)
    svc.drain()
    g.force_state("closed")
    warm_health = svc.health()

    stream = make_drift_scenario(ds.X, SLOTS, n_batches, scenario=scenario,
                                 severity=severity, seed=SEED)
    shift = max(1, n_batches // 3)
    per_batch = []
    for b, Q in enumerate(stream):
        rec, st = _serve_batch(svc, Q, _oracle(ds.X, Q))
        per_batch.append({"batch": b, "recall": rec,
                          "state": st["breaker_state"],
                          "drift": st["drift_score"]})
    h = svc.health()
    open_recs = [r["recall"] for r in per_batch
                 if r["state"] in ("open", "half_open")]
    first_open = next((r["batch"] for r in per_batch if r["state"] == "open"),
                      None)
    rep = sess.guardrails()
    row = {
        "scenario": scenario,
        "batches": n_batches,
        "shift_batch": shift,
        "first_open_batch": first_open,
        "opened_within_8": (first_open is not None
                            and first_open - shift <= 8),
        "recall_while_open": (float(min(open_recs)) if open_recs else None),
        "recall_mean_closed": float(np.mean(
            [r["recall"] for r in per_batch if r["state"] == "closed"])),
        "demoted_batches": rep["demoted_batches"],
        "final_state": rep["state"],
        "transitions": [f"{t['from']}->{t['to']} @b{t['batch']}: "
                        f"{t['reason']}" for t in rep["transitions"]
                        if t["reason"] != "forced"],
        "accounting_exact": (
            h["submitted"] - warm_health["submitted"]
            == h["completed"] - warm_health["completed"]),
        "per_batch": per_batch,
    }
    emit(f"robustness/drift/{ds.name}/{scenario}", 0.0,
         first_open="-" if first_open is None else first_open,
         open_recall="-" if row["recall_while_open"] is None
         else fmt3(row["recall_while_open"]),
         final=row["final_state"], ok=row["accounting_exact"])
    return row


def _control_cell(ds, pca, gcfg, n_batches: int, *, build: dict,
                  repeats: int = 3) -> dict:
    """No-drift twin run: guarded vs bare wall, `repeats` windows of one
    audit period each, median ratio — the measured price of the sentinel +
    1/64 shadow audits.  Median-of-windows because container timing jitter
    (2x swings; see verify notes) would otherwise dominate a single-window
    ratio whose true value is a few percent."""
    period = max(1, int(np.ceil(gcfg.audit_batch / (SLOTS * gcfg.audit_rate))))
    sess_g = _build_session(ds.X, pca, guardrails=gcfg, **build)
    sess_b = _build_session(ds.X, pca, **build)
    svc_g = sess_g.serve(slots=SLOTS, k=K)
    svc_b = sess_b.serve(slots=SLOTS, k=K)
    # in-distribution stream from the same generator the drift cells use
    total = repeats * n_batches
    stream = [make_ood_queries(ds.X, SLOTS, severity=0.0, seed=SEED + 1000 * b)
              for b in range(total + period)]
    # warm-up: compile both paths AND let the guarded run pass its first
    # audit (that shadow call's compile must not land in the measurement)
    g = sess_g.backend.guardrail
    for svc in (svc_g, svc_b):
        for Q in stream[:max(2, min(period + 1, len(stream) - total))]:
            for q in Q:
                svc.submit(q)
            svc.drain()
    if g.audits == 0:       # tiny runs: force the audit path to compile
        g._audit_acc = float(gcfg.audit_batch)
        for q in stream[0]:
            svc_g.submit(q)
        svc_g.drain()
    windows = []
    audits0 = g.audits
    for rep_i in range(repeats):
        walls = {"guarded": 0.0, "bare": 0.0}
        lo = len(stream) - total + rep_i * n_batches
        for Q in stream[lo:lo + n_batches]:
            for name, svc in (("guarded", svc_g), ("bare", svc_b)):
                tickets = [svc.submit(q) for q in Q]
                svc.step()
                walls[name] += tickets[0].service_s
        windows.append(walls["guarded"] / max(walls["bare"], 1e-12) - 1.0)
    rep = sess_g.guardrails()
    row = {
        "batches": n_batches,
        "repeats": repeats,
        "audit_period_batches": period,
        "audits_in_window": g.audits - audits0,
        "window_overhead_fracs": [float(w) for w in windows],
        "audit_overhead_frac": float(np.median(windows)),
        "breaker_stayed_closed": (rep["state"] == "closed"
                                  and rep["demoted_batches"] <= 1),
        "drift_score_end": rep["drift_score"],
    }
    emit(f"robustness/drift/{ds.name}/control", 0.0,
         overhead=fmt3(row["audit_overhead_frac"]),
         audits=row["audits_in_window"],
         closed=row["breaker_stayed_closed"])
    return row


def _drift_suite(ds, pca, *, dryrun: bool) -> dict:
    if dryrun:
        # tiny corpus: the block capacity is cut so severe OOD overflows the
        # per-block completion budget (the uncertified-evidence route) just
        # as it does at full scale with the default capacity
        build = dict(d1=32, row_block=128, block_capacity=16)
        gcfg = GuardrailConfig(min_dwell=2)
        n_batches, control_batches = 12, 6
        cells = ("sudden",)
    else:
        build = dict(d1=64)
        gcfg = GuardrailConfig()
        n_batches, control_batches = 36, 64
        cells = ("gradual", "sudden", "recovering")
    out = {
        "config": dataclasses.asdict(gcfg),
        "control": _control_cell(ds, pca, gcfg, control_batches, build=build),
        "cells": {c: _drift_cell(ds, pca, gcfg, c, n_batches, build=build)
                  for c in cells},
    }
    sudden = out["cells"].get("sudden")
    recov = out["cells"].get("recovering")
    out["accept"] = {
        "control_breaker_stayed_closed":
            bool(out["control"]["breaker_stayed_closed"]),
        "control_audit_overhead_le_5pct": (
            # wall-noise-prone on a 2-vCPU container; the dryrun corpus is
            # dispatch-dominated, so the overhead gate is full-run only
            True if dryrun
            else out["control"]["audit_overhead_frac"] <= 0.05),
        "sudden_opens_within_8_batches":
            bool(sudden and sudden["opened_within_8"]),
        "recall_while_open_1.000": all(
            c["recall_while_open"] is None or c["recall_while_open"] >= 1.0
            for c in out["cells"].values()),
        "recovering_repromotes": (
            True if recov is None
            else recov["final_state"] == "closed"),
        "accounting_exact_all_cells": all(
            c["accounting_exact"] for c in out["cells"].values()),
    }
    return out


# ---------------------------------------------------------------------------
# failover suite (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _failover_replay(svc, pool, qidx, arrivals, *, dead, kill_i, revive_i):
    """Poisson replay with a mid-stream kill and revive of replica ``dead``
    (both scheduled by submit index, installed via ``faults.install`` so
    the swap can straddle the loop).  Returns every ticket in submit
    order."""
    tickets, t, i = [], 0.0, 0
    prev, killed, revived = None, False, False
    try:
        while i < len(arrivals) or svc.pending:
            while i < len(arrivals) and arrivals[i] <= t:
                if not killed and i >= kill_i:
                    prev = faults.install(FaultPlan(dead_replica=dead))
                    killed = True
                elif killed and not revived and i >= revive_i:
                    faults.install(prev)
                    revived = True
                tickets.append(svc.submit(pool[qidx[i]], now=arrivals[i]))
                i += 1
            out = svc.step(now=t)
            if out:
                t = max(r.t_done for r in out)
            elif i < len(arrivals):
                t = max(t, arrivals[i])
            else:
                break
        svc.drain(now=t)
    finally:
        if killed and not revived:
            faults.install(prev)
    return tickets


def _kill_revive_cell(ds, *, dryrun: bool) -> dict:
    """Shard-mode tier through a kill -> degraded window -> revival."""
    n_req = 30 if dryrun else 90
    replicas, dead = 3, 1
    pol = ReplicaPolicy(max_retries=1, eject_after=1, probe_after=1,
                        promote_after=1, backoff_base_s=0.0, jitter=0.0,
                        hedge=False)
    svc = open_replicated(ds.X, replicas=replicas, mode="shard",
                          slots=8, k=K, replica_policy=pol, seed=SEED)
    pool = np.ascontiguousarray(ds.Q[:NQ_POOL], np.float32)
    oracle = _oracle(ds.X, pool)
    rng = np.random.default_rng(SEED + 3)
    qidx = [int(i % NQ_POOL) for i in range(n_req)]
    arrivals = np.cumsum(rng.exponential(1.0 / 200.0, n_req))
    tickets = _failover_replay(svc, pool, qidx, arrivals, dead=dead,
                               kill_i=n_req // 3, revive_i=2 * n_req // 3)
    h = svc.health()
    lost = sum(1 for r in tickets if r.status == "pending")
    done = [r for r in tickets if r.done]
    degraded = [r for r in done if r.stats[EXTRA_DEGRADED] == 1.0]
    full = [r for r in done if r.stats[EXTRA_DEGRADED] == 0.0]

    def _recall(rows):
        return (float(np.mean([np.isin(r.ids[:K], oracle[j]).mean()
                               for r, j in zip(tickets, qidx)
                               if r in rows])) if rows else None)

    deg_cov = (float(np.mean([r.coverage for r in degraded]))
               if degraded else None)
    deg_rec = _recall(degraded)
    rs = svc.replicas[dead]
    reasons = [t["reason"] for t in rs.breaker.transitions]
    row = {
        "n_requests": n_req,
        "replicas": replicas,
        "killed_replica": dead,
        "kill_at": n_req // 3,
        "revive_at": 2 * n_req // 3,
        "served": len(done),
        "lost_acknowledged": lost,
        "degraded_served": len(degraded),
        "degraded_coverage_mean": deg_cov,
        "degraded_recall": deg_rec,
        "full_recall": _recall(full),
        "dead_replica_final_state": rs.state,
        "dead_replica_transitions": [
            f"{t['from']}->{t['to']}: {t['reason']}"
            for t in rs.breaker.transitions],
        "accounting_exact": h["submitted"] == (
            h["completed"] + h["shed"] + h["timeouts"] + h["failures"]
            + svc.pending),
        "accept": {
            "lost_acknowledged_zero": lost == 0,
            "accounting_exact": None,      # filled below
            "outage_answers_flagged": bool(degraded) and all(
                r.coverage < 1.0 and not r.certified for r in degraded),
            # spatial partials are exact over the surviving union, so on
            # shuffled rows recall tracks coverage; 0.5x absorbs skew
            "degraded_recall_honest": (
                deg_rec is not None and deg_cov is not None
                and deg_rec >= 0.5 * deg_cov),
            "readmitted_after_revival": (
                rs.state == "closed"
                and any("re-admitted" in r for r in reasons)),
            "full_coverage_restored": bool(done)
            and done[-1].coverage == 1.0 and done[-1].certified is True,
        },
    }
    row["accept"]["accounting_exact"] = row["accounting_exact"]
    emit(f"robustness/failover/{ds.name}/kill_revive", 0.0,
         lost=lost, degraded=len(degraded),
         cov="-" if deg_cov is None else fmt3(deg_cov),
         recall="-" if deg_rec is None else fmt3(deg_rec),
         final=rs.state, ok=row["accounting_exact"])
    return row


def _hedge_cell(ds, *, dryrun: bool) -> dict:
    """Hedged vs unhedged p99 under one injected straggler replica, on the
    same Poisson arrivals and a deterministic virtual timer (walls are
    charged, not slept — both runs are replay-exact)."""
    n_req = 32 if dryrun else 96
    slow_s, fast_s = 0.06, 0.01
    rng = np.random.default_rng(SEED + 7)
    pool = np.ascontiguousarray(ds.Q[:NQ_POOL], np.float32)
    qidx = [int(i % NQ_POOL) for i in range(n_req)]
    # offered rate well under capacity: latency is the service wall, not
    # queue wait, so the hedged-vs-unhedged p99 gap is the hedge's doing
    arrivals = np.cumsum(rng.exponential(1.0 / 20.0, n_req))
    rows = {}
    for name, hedge in (("hedged", True), ("unhedged", False)):
        pol = ReplicaPolicy(hedge=hedge, hedge_factor=2.0,
                            hedge_min_delay_s=0.005, jitter=0.0, seed=SEED)
        svc = open_replicated(
            ds.X, replicas=3, mode="replicate", slots=4, k=K,
            replica_policy=pol, seed=SEED,
            timer=lambda idx, wall: slow_s if idx == 0 else fast_s)
        # warm-up: every replica gets a primary dispatch so the fleet p99
        # estimate exists before measurement (a cold-start straggler batch
        # can't hedge and would own the p99 by itself)
        for j in range(12):
            svc.submit(pool[j], now=-1.0 + 1e-3 * j)
        svc.drain(now=-0.5)
        tickets = _replay(svc, pool, qidx, arrivals)
        h = svc.health()
        lat = [r.latency_s for r in tickets if r.done]
        rows[name] = {
            "n_requests": n_req,
            "served": sum(1 for r in tickets if r.done),
            **latency_percentiles(lat),
            "hedges": h["hedges"],
            "hedge_wins": h["hedge_wins"],
            "hedge_losses": h["hedge_losses"],
            "accounting_exact": h["submitted"] == (
                h["completed"] + h["shed"] + h["timeouts"]
                + h["failures"] + svc.pending),
        }
    hp, up = rows["hedged"]["p99_ms"], rows["unhedged"]["p99_ms"]
    rows["straggler"] = {"replica": 0, "slow_wall_s": slow_s,
                         "fast_wall_s": fast_s}
    rows["accept"] = {
        "hedges_fired_and_won": (rows["hedged"]["hedges"] >= 1
                                 and rows["hedged"]["hedge_wins"] >= 1),
        "control_never_hedges": rows["unhedged"]["hedges"] == 0,
        "hedging_reduces_p99": hp is not None and up is not None and hp < up,
        "accounting_exact_both": (rows["hedged"]["accounting_exact"]
                                  and rows["unhedged"]["accounting_exact"]),
    }
    emit(f"robustness/failover/{ds.name}/hedge", 0.0,
         hedged_p99=f"{hp:.1f}", unhedged_p99=f"{up:.1f}",
         hedges=rows["hedged"]["hedges"], wins=rows["hedged"]["hedge_wins"],
         ok=rows["accept"]["hedging_reduces_p99"])
    return rows


def _failover_suite(ds, *, dryrun: bool) -> dict:
    kill = _kill_revive_cell(ds, dryrun=dryrun)
    hedge = _hedge_cell(ds, dryrun=dryrun)
    accept = {f"failover_{k}": v for k, v in kill.pop("accept").items()}
    accept.update({f"hedge_{k}": v for k, v in hedge.pop("accept").items()})
    return {"kill_revive": kill, "hedge": hedge, "accept": accept}


def main(json_path: str | None = None, *, dryrun: bool = False,
         scenario: str = "all") -> dict:
    if scenario not in SCENARIOS:
        raise ValueError(f"scenario must be one of {SCENARIOS}, got {scenario!r}")
    if dryrun:
        ds = load_dataset("sift", scale=0.04)       # ~400 x 128
    else:
        ds = dataset("sift")                        # 30k x 128
    pca = shared_pca(ds)
    out = {
        "benchmark": "serving robustness: Poisson overload (bounded queue, "
                     "deadlines, anytime partials) and query-drift "
                     "guardrails (sentinel + audits + circuit breaker, "
                     "DESIGN.md §9)",
        "dataset": {"name": ds.name, "n": ds.n, "dim": ds.dim},
        "k": K, "slots": SLOTS, "max_queue": MAX_QUEUE,
        "admission": "shed_oldest",
        "measurement_note":
            "2-vCPU container: service walls inherit up to +-40% "
            "run-to-run noise; rates are paired against one calibration "
            "so the shed/timeout/coverage ORDERING across 1x/2x/4x is the "
            "signal, absolute walls are not.  Drift cells are paired "
            "guarded-vs-bare for the same reason.",
        "accept": {},
    }
    if scenario in ("overload", "all"):
        ov = _overload_suite(ds, pca, dryrun=dryrun)
        out["overload"] = {k: v for k, v in ov.items() if k != "accept"}
        out["accept"].update(ov["accept"])
    if scenario in ("drift", "all"):
        dr = _drift_suite(ds, pca, dryrun=dryrun)
        out["drift"] = {k: v for k, v in dr.items() if k != "accept"}
        out["accept"].update(dr["accept"])
        if dryrun and not all(dr["accept"].values()):
            raise RuntimeError(
                f"guardrail drift smoke failed: {dr['accept']}")
    if scenario in ("failover", "all"):
        fo = _failover_suite(ds, dryrun=dryrun)
        out["failover"] = {k: v for k, v in fo.items() if k != "accept"}
        out["accept"].update(fo["accept"])
        if dryrun and not all(fo["accept"].values()):
            raise RuntimeError(
                f"failover chaos smoke failed: {fo['accept']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny corpus, 4x only / sudden cell only / short "
                         "failover replay, fault injection, no JSON (CI "
                         "smoke)")
    ap.add_argument("--scenario", choices=SCENARIOS, default="all",
                    help="which suite to run (default: all)")
    args = ap.parse_args()
    if args.dryrun:
        result = main(dryrun=True, scenario=args.scenario)
    else:
        result = main("BENCH_robustness.json", scenario=args.scenario)
    print(f"# accept: {result['accept']}")
