"""Fig. 8 + App. E: inner product & cosine via the Eq. 8 transform.

All methods run on the normalized dataset; IP/cosine top-k == L2 top-k there,
so QPS-recall curves mirror the Euclidean ones (Takeaway #3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALES, emit, fmt3, method_for, run_queries
from repro.api import METHODS, SearchSession
from repro.search.ivf import IVFIndex
from repro.vecdata import load_dataset

DATASETS = ("glove", "gist", "openai")
K = 10


def main():
    for ds_name in DATASETS:
        base = load_dataset(ds_name, scale=SCALES.get(ds_name, 0.3))
        ds = base.normalized()          # Eq. 8: IP == 1 - 0.5 d2 on unit norm
        idx = IVFIndex(n_list=64).build(ds.X)
        for name in METHODS:
            sess = SearchSession(method_for(ds, name, k=K), "ivf", idx)
            qps, rec, stats, us = run_queries(sess, ds, k=K, nq=12)
            # verify the transform: L2 top-1 == IP top-1 for a sample query
            q = ds.Q[0]
            ip_top = int(np.argmax(ds.X @ q))
            l2_top = int(np.argmin(((ds.X - q) ** 2).sum(1)))
            emit(f"metric_ip/{ds_name}/{name}", us,
                 qps=f"{qps:.1f}", recall=fmt3(rec),
                 prune=fmt3(stats.pruning_ratio),
                 ip_l2_top1_agree=int(ip_top == l2_top))


if __name__ == "__main__":
    main()
