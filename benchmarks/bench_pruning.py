"""Fig. 6: dimension pruning ratio + recall across dimensionality.

Validates: pruning is dimension-dependent; recall stays ~native."""
from __future__ import annotations

from benchmarks.common import dataset, emit, fmt3, run_queries, session_for
from repro.api import METHODS

DATASETS = ("deep", "gist", "openai")
K = 10


def main():
    for ds_name in DATASETS:
        ds = dataset(ds_name)
        for name in METHODS:
            sess = session_for(ds, name, k=K)
            qps, rec, stats, us = run_queries(sess, ds, k=K, nq=12)
            emit(f"pruning/{ds_name}/{name}", us,
                 prune=fmt3(stats.pruning_ratio), recall=fmt3(rec),
                 dco_true_frac=fmt3(stats.n_true / max(stats.n_dco, 1)))


if __name__ == "__main__":
    main()
