"""Fig. 7: in-distribution vs out-of-distribution queries (multimodal).

Validates finding (2): pruning collapses and SOTA QPS degrades on OOD."""
from __future__ import annotations

from benchmarks.common import dataset, emit, fmt3, run_queries, session_for
from repro.api import METHODS

DATASETS = ("text2image", "laion")
K = 10


def main():
    for ds_name in DATASETS:
        ds = dataset(ds_name)
        for name in METHODS:
            sess = session_for(ds, name, k=K)
            qps_in, rec_in, st_in, us_in = run_queries(sess, ds, k=K, nq=12)
            qps_ood, rec_ood, st_ood, us_ood = run_queries(
                sess, ds, k=K, nq=12, queries=ds.Q_ood)
            emit(f"ood/{ds_name}/{name}", us_ood,
                 qps_in=f"{qps_in:.1f}", qps_ood=f"{qps_ood:.1f}",
                 recall_in=fmt3(rec_in), recall_ood=fmt3(rec_ood),
                 prune_in=fmt3(st_in.pruning_ratio),
                 prune_ood=fmt3(st_ood.pruning_ratio))


if __name__ == "__main__":
    main()
