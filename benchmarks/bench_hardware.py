"""Fig. 13: hardware/execution-model sensitivity.

The paper's axis is CPU-SIMD-off / CPU-SIMD-on / GPU.  The analogous axis in
this framework:
  scalar   — pure-Python per-dimension loop (SIMD-off analogue)
  batched  — numpy vectorized staged scan (SIMD-on analogue)
  device   — jit'd two-stage batched engine, per-query-batch prep
             (TPU execution model; runs on CPU backend here, and its roofline
             on the production mesh is in EXPERIMENTS.md §Roofline)

Validates Takeaway #6: the ranking of methods flips across execution models —
e.g. early-exit wins scalar, loses batched."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fmt3
from repro.core.engine import QueryBatch, make_schedule, scan_topk
from repro.core.methods import make_method
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

K = 10


def scalar_scan(m, ctx, qi, X, tau_sq, schedule):
    """Per-vector, per-stage Python loop — the no-SIMD analogue."""
    Xr = m.state.get("Xrot", m.state["X"])
    qr = ctx.get("Qrot", ctx["Q"])[qi]
    survivors = 0
    for row in range(X.shape[0]):
        partial = 0.0
        pruned = False
        for d in schedule:
            seg = Xr[row, :d] - qr[:d]
            partial = float(seg @ seg)
            keep, _ = m.screen(np.array([row]), ctx, qi, d, tau_sq)
            if not keep[0]:
                pruned = True
                break
        if not pruned:
            survivors += 1
    return survivors


def main():
    for ds_name in ("sift", "gist"):
        ds = load_dataset(ds_name, scale=0.05)
        sched = make_schedule(ds.dim)
        gt, gtd = ds.ground_truth(K)
        sub = np.arange(min(ds.n, 400))           # scalar loop slice
        for name in ("FDScanning", "PDScanning", "PDScanning+", "ADSampling",
                     "DDCres"):
            m = make_method(name).fit(ds.X)
            batch = QueryBatch.create(m, ds.Q[:4], sched)
            ctx = batch.ctx
            tau = float(gtd[0, -1])
            # scalar
            t0 = time.perf_counter()
            scalar_scan(m, ctx, 0, ds.X[sub], tau, m.stage_dims(sched) or [ds.dim])
            t_scalar = time.perf_counter() - t0
            # batched numpy
            t0 = time.perf_counter()
            for qi in range(4):
                scan_topk(m, batch, qi, np.arange(ds.n), K)
            t_batch = (time.perf_counter() - t0) / 4
            emit(f"hardware/{ds_name}/{name}", 1e6 * t_batch,
                 scalar_us_per_vec=fmt3(1e6 * t_scalar / len(sub)),
                 batched_us_per_vec=fmt3(1e6 * t_batch / ds.n),
                 simd_analog_speedup=fmt3((t_scalar / len(sub))
                                          / (t_batch / ds.n)))

    # device engines (jit two-stage vs streaming) on one dataset
    import jax.numpy as jnp
    from repro.core.jax_engine import (DcoEngineConfig, build_device_state,
                                       two_stage_topk)
    from repro.core.stream_engine import build_stream_blocks, stream_topk
    ds = load_dataset("gist", scale=0.2)
    m = make_method("PDScanning+").fit(ds.X)
    cfg = DcoEngineConfig(kind="lb", d1=128, k=K, capacity=1024, query_chunk=8)
    state = build_device_state(m, cfg.d1)
    # pre-build the streaming layout (the facade caches it the same way) so
    # the timed loop measures steady-state throughput, not the pad copy
    blocks = build_stream_blocks(state, cfg.row_block)
    W = jnp.asarray(m.state["pca"]["W"])
    Q = jnp.asarray(ds.Q[:16]) @ W
    gt, _ = ds.ground_truth(K)
    ql, qt = Q[:, :cfg.d1], Q[:, cfg.d1:]
    for tag, fn in (
            ("device_two_stage", lambda: two_stage_topk(state, ql, qt, cfg)),
            ("device_stream",
             lambda: stream_topk(state, ql, qt, cfg, blocks=blocks))):
        out = fn()                                 # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn()
            out[0].block_until_ready()
        dt = (time.perf_counter() - t0) / 3 / 16
        rec = recall_at_k(np.array(out[1]), gt[:16])
        emit(f"hardware/gist/{tag}", 1e6 * dt, recall=fmt3(rec),
             survivors_mean=fmt3(float(np.mean(np.array(out[2])))))


if __name__ == "__main__":
    main()
