"""Fig. 9/10: DCO-accelerated index construction + post-build search parity.

Classification methods are excluded (they need an index to train — paper
§V-D).  IVF construction DCOs are the per-vector assignment top-1 searches
(method fitted on the CENTROIDS, base rows act as queries); every method —
including FDScanning — runs through the same staged-scan loop so the
comparison isolates the DCO, exactly as the paper's unified framework does.
HNSW construction runs on a reduced slice (host graph)."""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit, fmt3, method_for, run_queries
from repro.api import SearchSession
from repro.core.engine import QueryBatch, ScanStats, make_schedule
from repro.core.methods import make_method
from repro.search.hnsw import HNSWIndex
from repro.search.ivf import IVFIndex, _kmeans_assign
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

METHODS = ("FDScanning", "PDScanning", "PDScanning+", "ADSampling", "DADE",
           "DDCres")
K = 10


def ivf_construction():
    for ds_name in ("glove", "gist", "openai"):
        ds = dataset(ds_name)
        # shared centroids (identical final layout for all methods — App. A)
        proto = IVFIndex(n_list=64).build(ds.X)
        cents = proto.centroids
        n_assign = min(ds.n, 4000)              # assignment slice to time
        sched = make_schedule(ds.dim, delta0=16, delta_d=32, max_stages=3)
        base_t = None
        for name in METHODS:
            cm = make_method(name).fit(cents)   # method scans CENTROIDS
            stats = ScanStats()
            t0 = time.perf_counter()
            _kmeans_assign(ds.X[:n_assign], cents, method=cm, schedule=sched,
                           stats=stats)
            build_t = time.perf_counter() - t0
            if base_t is None:
                base_t = build_t
            sess = SearchSession(method_for(ds, "FDScanning", k=K), "ivf", proto)
            qps, rec, _, _ = run_queries(sess, ds, k=K, nq=8)
            emit(f"construct_ivf/{ds_name}/{name}", 1e6 * build_t / n_assign,
                 assign_s=fmt3(build_t), speedup=fmt3(base_t / build_t),
                 prune=fmt3(stats.pruning_ratio), post_recall=fmt3(rec))


def hnsw_construction():
    ds = load_dataset("gist", scale=0.06)       # ~1.8k vectors
    sched = make_schedule(ds.dim, delta0=32, delta_d=64)
    base_t = None
    for name in METHODS:
        m = make_method(name).fit(ds.X)
        stats = ScanStats()
        t0 = time.perf_counter()
        idx = HNSWIndex(m=8, ef_construction=32).build(ds.X, method=m,
                                                       schedule=sched,
                                                       stats=stats)
        build_t = time.perf_counter() - t0
        if base_t is None:
            base_t = build_t
        batch = QueryBatch.create(m, ds.Q[:10], sched)
        gt, _ = ds.ground_truth(K)
        found = [idx.search(m, batch, qi, K, ef=48)[1] for qi in range(10)]
        rec = recall_at_k(found, gt[:10])
        emit(f"construct_hnsw/gist/{name}", 1e6 * build_t,
             build_s=fmt3(build_t), speedup=fmt3(base_t / build_t),
             prune=fmt3(stats.pruning_ratio), search_recall=fmt3(rec))


def main():
    ivf_construction()
    hnsw_construction()


if __name__ == "__main__":
    main()
