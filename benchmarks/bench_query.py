"""Fig. 4/5: query QPS-recall across datasets x all 8 DCO methods (IVF).

Validates finding (1): SOTA DCOs win at moderate D, lose at low D (deep,
glove) and stop paying at ultra-high D (trevi, xultra) where the O(D^2)
online rotation dominates.  Runs entirely through the ``repro.api`` facade,
whose ``search(Q)`` rotates the whole batch in one matmul — the per-query
rotation FLOPs are unchanged (D^2 each), only fixed call overhead is
amortized, so the dimensionality trend is measured on the system's real
serving path.
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, fmt3, run_queries, session_for
from repro.api import METHODS

DATASETS = ("deep", "glove", "sift", "gist", "openai", "trevi", "xultra")
K = 10


def main():
    for ds_name in DATASETS:
        ds = dataset(ds_name)
        base_qps = None
        for name in METHODS:
            sess = session_for(ds, name, k=K)
            qps, rec, stats, us = run_queries(sess, ds, k=K, nq=15)
            if name == "FDScanning":
                base_qps = qps
            emit(f"query/{ds_name}/{name}", us,
                 qps=f"{qps:.1f}", recall=fmt3(rec),
                 prune=fmt3(stats.pruning_ratio),
                 speedup_vs_fd=fmt3(qps / base_qps))


if __name__ == "__main__":
    main()
