"""Fig. 4/5: query QPS-recall across datasets x all 8 DCO methods (IVF).

Validates finding (1): SOTA DCOs win at moderate D, lose at low D (deep,
glove) and at ultra-high D (trevi, xultra) where the O(D^2) per-query
rotation dominates.
"""
from __future__ import annotations

from benchmarks.common import (dataset, emit, fmt3, ivf_for, method_for,
                               run_queries)
from repro.core.methods import ALL_METHODS

DATASETS = ("deep", "glove", "sift", "gist", "openai", "trevi", "xultra")
K = 10


def main():
    for ds_name in DATASETS:
        ds = dataset(ds_name)
        idx = ivf_for(ds)
        base_qps = None
        for name in ALL_METHODS:
            m = method_for(ds, name, k=K)
            qps, rec, stats, us = run_queries(ds, m, idx, k=K, nq=15)
            if name == "FDScanning":
                base_qps = qps
            emit(f"query/{ds_name}/{name}", us,
                 qps=f"{qps:.1f}", recall=fmt3(rec),
                 prune=fmt3(stats.pruning_ratio),
                 speedup_vs_fd=fmt3(qps / base_qps))


if __name__ == "__main__":
    main()
