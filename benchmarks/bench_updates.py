"""Fig. 11/12: dynamic inserts + limited-initial-data sensitivity.

Fig. 11: 60% base HNSW build, 40% inserted in 4 batches — per-batch QPS,
recall, cumulative update time per method (transforms fitted ONCE on the
base set; inserts use the facade's ``add``, never refit — the paper's
dynamic setting).
Fig. 12: methods fitted on 1% / 5% / 100% of the data — pruning + recall."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fmt3
from repro.api import SchedulePolicy, open_index
from repro.core.engine import QueryBatch, ScanStats, make_schedule, scan_topk
from repro.core.methods import make_method
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

METHODS = ("FDScanning", "PDScanning", "PDScanning+", "ADSampling", "DADE",
           "DDCres")
K = 10


def dynamic_inserts():
    ds = load_dataset("gist", scale=0.05)          # 1.5k vectors
    n_base = int(ds.n * 0.6)
    batches = np.array_split(np.arange(n_base, ds.n), 4)
    for name in METHODS:
        sess = open_index(ds.X[:n_base], index="hnsw", method=name,
                          schedule=SchedulePolicy(delta0=32, delta_d=64),
                          index_params={"m": 8, "ef_construction": 32})
        total_update = 0.0
        for ids in batches:
            t0 = time.perf_counter()
            sess.add(ds.X[ids])
            total_update += time.perf_counter() - t0
        # search after all inserts
        res = sess.search(ds.Q[:10], K, ef=48)
        gt, _ = ds.ground_truth(K)
        rec = recall_at_k(res.ids, gt[:10])
        emit(f"updates_insert/gist/{name}", 1e6 * total_update,
             update_s=fmt3(total_update), qps=f"{res.qps:.1f}",
             recall=fmt3(rec))


def limited_initial_data():
    ds = load_dataset("gist", scale=0.2)            # 6k vectors
    sched = make_schedule(ds.dim)
    gt, gtd = ds.ground_truth(K)
    for frac in (0.01, 0.05, 1.0):
        n_fit = max(64, int(ds.n * frac))
        for name in ("PDScanning+", "DADE", "DDCres", "DDCpca", "DDCopq"):
            m = make_method(name).fit(ds.X[:n_fit])
            m.append(ds.X[n_fit:])
            if m.needs_training:
                rng = np.random.default_rng(3)
                m.train(ds.X[rng.choice(n_fit, min(16, n_fit))], K, sched)
            stats = ScanStats()
            batch = QueryBatch.create(m, ds.Q[:10], sched, stats)
            found = []
            for qi in range(10):
                _, ids = scan_topk(m, batch, qi, np.arange(ds.n), K)
                found.append(ids)
            rec = recall_at_k(np.array(found), gt[:10])
            emit(f"updates_limited/gist/{name}/fit{frac}", 0.0,
                 fit_frac=frac, recall=fmt3(rec),
                 prune=fmt3(stats.pruning_ratio))


def main():
    dynamic_inserts()
    limited_initial_data()


if __name__ == "__main__":
    main()
