"""Tail latency of the serving front under mixed read/write load.

Drives ``repro.serving.SearchService`` (continuous batching over one
``SearchSession``) with a discrete-event simulation: Poisson query arrivals
are replayed against *measured* service walls — ``submit``/``step`` take
explicit ``now`` timestamps, so the arrival process costs no sleeping and
the recorded latencies are queueing + the real device walls of this
container.  Inserts interleave with the query stream (every ~25 requests a
chunk of held-out corpus rows is added through the session), which is the
scenario the LSM-style delta write path (DESIGN.md §6) exists for.

Cells: query mix {id, ood_mix (50/50 spectrum-shifted)} x write path
{delta (policy default), rebuild (delta_merge_threshold=0 — every insert
re-materializes the device layout, the pre-delta behavior)}.  All four
cells replay the SAME arrival times, queries, and insert chunks at the same
offered rate (0.7x the measured full-batch service rate), over the same
fitted method state (PDScanning+ with the adaptive policy — certified
exact by construction, so recall must be 1.000 everywhere).

Per cell: p50/p95/p99 latency (benchmarks/common.latency_percentiles),
sustained QPS over the simulated makespan, per-request recall against the
ground truth of the corpus *visible when each request was served*, and
insert amplification (device rows written / rows inserted, from the
backend's write counters).  Writes BENCH_serving.json; ``--dryrun`` is the
CI smoke (tiny corpus, one cell, no JSON).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import (dataset, emit, fmt3, latency_percentiles,
                               shared_pca)
from repro.api import SchedulePolicy, SearchSession
from repro.core.methods import make_method
from repro.vecdata.synthetic import load_dataset, make_ood_queries

K, SLOTS, NQ_POOL = 10, 16, 64
LAMBDA_FRACTION = 0.7          # offered rate vs measured service rate
SEED = 11


def _build_session(X_base, pca, *, d1, delta_merge_threshold):
    pol = SchedulePolicy(d1=d1, query_chunk=SLOTS, adaptive=True,
                         delta_merge_threshold=delta_merge_threshold)
    m = make_method("PDScanning+", pca=pca).fit(X_base)
    return SearchSession(m, "flat", None, "jax", pol)


def _gt_cache(d2, visible_sizes):
    """Exact top-K ids of every pool query over each visible corpus prefix
    (one argpartition per distinct ``n_visible`` a request can observe)."""
    row = np.arange(d2.shape[0])[:, None]
    out = {}
    for n in visible_sizes:
        idx = np.argpartition(d2[:, :n], K - 1, axis=1)[:, :K]
        out[n] = idx[row, np.argsort(d2[row, idx], axis=1)]
    return out


def _simulate(svc, pool, qidx, arrivals, inserts):
    """Replay the workload in simulated time.

    ``inserts`` is [(after_request_index, chunk)]: each chunk is added the
    instant its trigger request arrives; the add's measured wall blocks the
    serving loop (writes share the serving thread).  Returns (served
    requests, {rid: pool query index}).
    """
    events = [("q", arrivals[i], i) for i in range(len(arrivals))]
    events += [("w", arrivals[ridx] + 1e-9, chunk)
               for ridx, chunk in inserts]
    events.sort(key=lambda e: e[1])
    t, i, served, rid_to_q = 0.0, 0, [], {}
    while i < len(events) or svc.pending:
        while i < len(events) and events[i][1] <= t:
            kind, te, payload = events[i]
            i += 1
            if kind == "q":
                req = svc.submit(pool[qidx[payload]], now=te)
                rid_to_q[req.rid] = qidx[payload]
            else:
                t += svc.add(payload, now=te)["wall_s"]
        if svc.pending:
            batch = svc.step(now=t)
            served += batch
            t = batch[0].t_done
        elif i < len(events):
            t = max(t, events[i][1])
        else:
            break
    return served, rid_to_q


def _calibrate(svc, pool, insert_chunk) -> tuple:
    """(steady full-batch wall, post-insert stall), both seconds.

    The offered rate must budget for BOTH costs: a mixed workload's
    capacity is queries/steady_wall only between writes — the first step
    after an insert additionally pays the delta rebuild (or, on the rebuild
    path, the full re-materialization), and an arrival process calibrated
    to the pure query rate saturates every cell.  Measured on a throwaway
    session so the cells' corpora stay untouched."""
    for j in range(SLOTS):              # warm the main scan
        svc.submit(pool[j % len(pool)])
    svc.drain()
    svc.add(insert_chunk[:8])           # warm the delta-segment shape
    for j in range(SLOTS):              # (one-time scan compile)
        svc.submit(pool[j % len(pool)])
    svc.drain()
    insert_chunk = insert_chunk[8:]
    steady = np.inf
    for _ in range(3):
        for j in range(SLOTS):
            svc.submit(pool[j % len(pool)])
        steady = min(steady, svc.step()[0].service_s)
        svc.drain()
    svc.add(insert_chunk)
    for j in range(SLOTS):
        svc.submit(pool[j % len(pool)])
    post = svc.step()[0].service_s
    svc.drain()
    return steady, max(post - steady, 0.0)


def _workload(ds, n_base, *, n_req, insert_every, insert_rows, lam, rng):
    """Arrival times + insert chunks + per-mix query pools, shared by every
    cell so the comparison is controlled."""
    qid = ds.Q[:NQ_POOL]
    qood = make_ood_queries(ds.X, NQ_POOL, severity=1.0)
    pool = np.concatenate([qid, qood])
    arrivals = np.cumsum(rng.exponential(1.0 / lam, n_req))
    # ood_mix alternates id / ood per request — the production interleave
    qidx = {"id": [i % NQ_POOL for i in range(n_req)],
            "ood_mix": [(i % NQ_POOL) + (i % 2) * NQ_POOL
                        for i in range(n_req)]}
    inserts, start = [], n_base + 8          # +8: the warm-up insert
    for ridx in range(insert_every, n_req, insert_every):
        inserts.append((ridx, ds.X[start:start + insert_rows]))
        start += insert_rows
    visible = sorted({n_base + 8} | {n_base + 8 + insert_rows * (j + 1)
                                     for j in range(len(inserts))})
    return pool, qidx, arrivals, inserts, visible


def main(json_path: str | None = None, *, dryrun: bool = False) -> dict:
    if dryrun:
        ds = load_dataset("sift", scale=0.12)       # ~1.2k x 128
        n_req, insert_every, insert_rows, d1 = 24, 10, 32, 32
        mixes, thresholds = ("id",), {"delta": 4096}
    else:
        ds = dataset("laion")                       # 20k x 512
        n_req, insert_every, insert_rows, d1 = 160, 25, 128, 64
        mixes = ("id", "ood_mix")
        thresholds = {"delta": 4096, "rebuild": 0}
    n_base = ds.n - 8 - insert_rows * ((n_req - 1) // insert_every + 1)
    pca = shared_pca(ds)

    # capacity calibrated once (throwaway delta session, id queries) and
    # shared, so every cell faces the same offered load
    sess0 = _build_session(ds.X[:n_base], pca, d1=d1,
                           delta_merge_threshold=thresholds["delta"])
    steady_s, stall_s = _calibrate(
        sess0.serve(slots=SLOTS, k=K), ds.Q[:NQ_POOL],
        ds.X[n_base:n_base + 8 + insert_rows])
    n_inserts = (n_req - 1) // insert_every
    # LAMBDA_FRACTION of the mixed-workload capacity: queries at the steady
    # full-batch rate plus one rebuild stall per insert event
    lam = (LAMBDA_FRACTION * n_req
           / (n_req * steady_s / SLOTS + n_inserts * stall_s))
    del sess0
    rng = np.random.default_rng(SEED)
    pool, qidx, arrivals, inserts, visible = _workload(
        ds, n_base, n_req=n_req, insert_every=insert_every,
        insert_rows=insert_rows, lam=lam, rng=rng)
    d2 = ((ds.X ** 2).sum(1)[None, :] - 2.0 * pool @ ds.X.T
          + (pool ** 2).sum(1)[:, None])
    gt = _gt_cache(d2, visible)

    rows = []
    for write_path, thresh in thresholds.items():
        for mix in mixes:
            sess = _build_session(ds.X[:n_base], pca, d1=d1,
                                  delta_merge_threshold=thresh)
            svc = sess.serve(slots=SLOTS, k=K)
            for j in range(SLOTS):                  # warm the main scan
                svc.submit(pool[j % NQ_POOL])
            svc.drain()
            svc.add(ds.X[n_base:n_base + 8])        # warm the post-insert
            for j in range(SLOTS):                  # shape (delta / rebuild)
                svc.submit(pool[j % NQ_POOL])
            svc.drain()
            base_w = sess.backend.rows_written
            base_i = sess.backend.rows_inserted
            served, rid_to_q = _simulate(svc, pool, qidx[mix], arrivals,
                                         inserts)
            lat = [r.latency_s for r in served]
            recalls = [np.isin(r.ids[:K],
                               gt[r.n_visible][rid_to_q[r.rid]]).mean()
                       for r in served]
            n_ins = sess.backend.rows_inserted - base_i
            makespan = (max(r.t_done for r in served)
                        - min(r.t_submit for r in served))
            row = {
                "mix": mix, "write_path": write_path,
                "offered_qps": lam, "n_requests": len(served),
                "sustained_qps": len(served) / makespan,
                **latency_percentiles(lat),
                "mean_latency_ms": float(1e3 * np.mean(lat)),
                "mean_batch_size": float(np.mean(
                    [r.batch_size for r in served])),
                "recall": float(np.mean(recalls)),
                "certified_fraction": float(np.mean(
                    [r.certified for r in served])),
                "rows_inserted": int(n_ins),
                "insert_amplification": float(
                    (sess.backend.rows_written - base_w) / max(n_ins, 1)),
                "write_modes": dict(svc.write_modes),
                "merges": int(sess.backend.merges),
            }
            rows.append(row)
            emit(f"serving/{ds.name}/{mix}/{write_path}",
                 1e3 * row["p50_ms"],
                 p99_ms=f"{row['p99_ms']:.1f}",
                 qps=f"{row['sustained_qps']:.1f}",
                 recall=fmt3(row["recall"]),
                 certified=fmt3(row["certified_fraction"]),
                 amp=f"{row['insert_amplification']:.1f}",
                 batch=f"{row['mean_batch_size']:.1f}")

    def cell(write_path, key):
        return [r[key] for r in rows if r["write_path"] == write_path]
    out = {
        "benchmark": "serving-front tail latency under Poisson arrivals "
                     "with interleaved inserts (discrete-event replay of "
                     "measured service walls; controlled: same fitted "
                     "state, arrival times, queries, and insert chunks in "
                     "every cell)",
        "dataset": {"name": ds.name, "n_base": n_base, "dim": ds.dim},
        "k": K, "slots": SLOTS, "d1": d1,
        "lambda_fraction": LAMBDA_FRACTION, "offered_qps": lam,
        "calibration": {"steady_step_ms": 1e3 * steady_s,
                        "insert_stall_ms": 1e3 * stall_s},
        "insert_every": insert_every, "insert_rows": insert_rows,
        "measurement_note":
            "2-vCPU container: service walls inherit up to +-40% "
            "run-to-run noise; the delta-vs-rebuild contrast is paired "
            "(identical workload replay) so the amplification and tail "
            "ordering are meaningful even when absolute walls drift.",
        "accept": {
            "recall_1.0_all_cells": all(r["recall"] >= 1.0 for r in rows),
            "all_requests_certified": all(
                r["certified_fraction"] >= 1.0 for r in rows),
            "delta_amplification_below_rebuild": (
                max(cell("delta", "insert_amplification"), default=0.0)
                < min(cell("rebuild", "insert_amplification"),
                      default=np.inf)) if not dryrun else True,
        },
        "rows": rows,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny corpus, one cell, no JSON (CI smoke)")
    args = ap.parse_args()
    if args.dryrun:
        result = main(dryrun=True)
    else:
        result = main("BENCH_serving.json")
    print(f"# accept: {result['accept']}")
