"""Shared benchmark plumbing: dataset/method caches, facade sessions, CSV.

Output convention (benchmarks/run.py): every row is
    name,us_per_call,derived
where ``derived`` carries the figure-specific metric (recall, pruning ratio,
speedup, ...) as ``key=value|key=value``.

All query-path benchmarks go through ``repro.api.SearchSession`` — the same
facade the examples use — so a benchmark is "pick a session, call
``run_queries``".  Methods and IVF layouts are cached per dataset because
every figure sweeps all 8 methods over one shared index (paper App. A:
identical data layout across methods).
"""
from __future__ import annotations

import numpy as np

from repro.api import SearchSession, SchedulePolicy
from repro.core import transforms as T
from repro.core.engine import make_schedule
from repro.core.methods import make_method
from repro.search.ivf import IVFIndex
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

# CPU-feasible scales per dataset family (keeps every figure < ~2 min)
SCALES = {"deep": 0.15, "glove": 0.3, "sift": 0.3, "text2image": 0.2,
          "laion": 0.4, "wikipedia": 0.4, "gist": 0.5, "openai": 0.5,
          "trevi": 0.5, "xultra": 0.5}

_PCA_CACHE: dict = {}
_METHOD_CACHE: dict = {}
_IVF_CACHE: dict = {}


def dataset(name):
    return load_dataset(name, scale=SCALES.get(name, 0.3))


def shared_pca(ds):
    if ds.name not in _PCA_CACHE:
        _PCA_CACHE[ds.name] = T.fit_pca(ds.X)
    return _PCA_CACHE[ds.name]


def method_for(ds, name, k=10, schedule=None, **params):
    key = (ds.name, name, k)
    if key in _METHOD_CACHE:
        return _METHOD_CACHE[key]
    if name in ("PDScanning+", "DADE", "DDCres", "DDCpca"):
        params.setdefault("pca", shared_pca(ds))
    if name == "DDCopq":
        params.setdefault("n_sub", 8)
        params.setdefault("n_codes", 128)
    m = make_method(name, **params).fit(ds.X)
    if m.needs_training:
        rng = np.random.default_rng(7)
        m.train(ds.X[rng.choice(ds.n, 24)], k,
                schedule or make_schedule(ds.dim))
    _METHOD_CACHE[key] = m
    return m


def ivf_for(ds, n_list=64):
    if ds.name not in _IVF_CACHE:
        _IVF_CACHE[ds.name] = IVFIndex(n_list=n_list).build(ds.X)
    return _IVF_CACHE[ds.name]


def session_for(ds, name, *, k=10, index="ivf", backend="host",
                policy: SchedulePolicy | None = None) -> SearchSession:
    """Facade session over the cached method + shared index for ``ds``.
    HNSW graphs aren't cached here (host builds are slow) — construct those
    explicitly, as bench_query_hnsw does."""
    if index not in ("ivf", "flat"):
        raise ValueError(f"session_for caches ivf/flat only, got {index!r}")
    m = method_for(ds, name, k=k)
    idx = ivf_for(ds) if index == "ivf" else None
    return SearchSession(m, index, idx, backend, policy)


def run_queries(sess: SearchSession, ds, *, k=10, nprobe=16, nq=20,
                queries=None):
    """One batched facade search; returns (qps, recall, stats, us_per_query)
    including the batch-amortized online pre-processing cost."""
    Q = (ds.Q if queries is None else queries)[:nq]
    res = sess.search(Q, k, nprobe=nprobe)
    gt, _ = ds.ground_truth(k, ood=queries is not None)
    rec = recall_at_k(res.ids, gt[:len(Q)])
    return res.qps, rec, res.stats, 1e6 * res.wall_time_s / len(Q)


def latency_percentiles(samples_s) -> dict:
    """Tail-latency summary of per-request latencies (seconds in, ms out):
    ``{"p50_ms", "p95_ms", "p99_ms"}`` — the serving suite's headline shape."""
    a = np.asarray(list(samples_s), np.float64)
    if a.size == 0:
        raise ValueError("latency_percentiles: empty sample")
    return {f"p{p}_ms": float(1e3 * np.quantile(a, p / 100.0))
            for p in (50, 95, 99)}


def emit(name, us, **derived):
    d = "|".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.1f},{d}", flush=True)


def fmt3(x):
    return f"{x:.3f}"
