"""Shared benchmark plumbing: dataset/method caches, timing, CSV convention.

Output convention (benchmarks/run.py): every row is
    name,us_per_call,derived
where ``derived`` carries the figure-specific metric (recall, pruning ratio,
speedup, ...) as ``key=value|key=value``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import transforms as T
from repro.core.engine import ScanStats, make_schedule, scan_topk
from repro.core.methods import ALL_METHODS, make_method
from repro.search.ivf import IVFIndex
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

# CPU-feasible scales per dataset family (keeps every figure < ~2 min)
SCALES = {"deep": 0.15, "glove": 0.3, "sift": 0.3, "text2image": 0.2,
          "laion": 0.4, "wikipedia": 0.4, "gist": 0.5, "openai": 0.5,
          "trevi": 0.5, "xultra": 0.5}

_PCA_CACHE: dict = {}
_METHOD_CACHE: dict = {}
_IVF_CACHE: dict = {}


def dataset(name):
    return load_dataset(name, scale=SCALES.get(name, 0.3))


def shared_pca(ds):
    if ds.name not in _PCA_CACHE:
        _PCA_CACHE[ds.name] = T.fit_pca(ds.X)
    return _PCA_CACHE[ds.name]


def method_for(ds, name, k=10, schedule=None, **params):
    key = (ds.name, name, k)
    if key in _METHOD_CACHE:
        return _METHOD_CACHE[key]
    if name in ("PDScanning+", "DADE", "DDCres", "DDCpca"):
        params.setdefault("pca", shared_pca(ds))
    if name == "DDCopq":
        params.setdefault("n_sub", 8)
        params.setdefault("n_codes", 128)
    m = make_method(name, **params).fit(ds.X)
    if m.needs_training:
        rng = np.random.default_rng(7)
        m.train(ds.X[rng.choice(ds.n, 24)], k,
                schedule or make_schedule(ds.dim))
    _METHOD_CACHE[key] = m
    return m


def ivf_for(ds, n_list=64):
    if ds.name not in _IVF_CACHE:
        _IVF_CACHE[ds.name] = IVFIndex(n_list=n_list).build(ds.X)
    return _IVF_CACHE[ds.name]


def run_queries(ds, m, idx, *, k=10, nprobe=16, nq=20, schedule=None,
                queries=None, per_query_prep=True):
    """Returns (qps, recall, stats, us_per_query) including the paper's
    per-query online pre-processing cost (prep batch of 1)."""
    Q = ds.Q[:nq] if queries is None else queries[:nq]
    schedule = schedule or make_schedule(ds.dim)
    stats = ScanStats()
    found = []
    t0 = time.perf_counter()
    for qi in range(len(Q)):
        if per_query_prep:
            ctx = m.prep_queries(Q[qi:qi + 1])
            d, ids = idx.search(m, ctx, 0, Q[qi], k, nprobe, schedule, stats)
        else:
            ctx = m.prep_queries(Q)
            d, ids = idx.search(m, ctx, qi, Q[qi], k, nprobe, schedule, stats)
        found.append(ids)
    dt = time.perf_counter() - t0
    gt, _ = ds.ground_truth(k, ood=queries is not None)
    rec = recall_at_k(np.array(found), gt[:len(Q)])
    return len(Q) / dt, rec, stats, 1e6 * dt / len(Q)


def emit(name, us, **derived):
    d = "|".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.1f},{d}", flush=True)


def fmt3(x):
    return f"{x:.3f}"
