"""Fig. 4 on the paper's actual CPU index (HNSW), reduced scale.

Optional suite (not in the default run list — host-graph builds are slow on
1 core):  PYTHONPATH=src python -m benchmarks.run --only query_hnsw

Scale caveat (EXPERIMENTS.md §Repro note): at ~2k vectors each HNSW hop
screens a <=16-candidate batch, so fixed per-stage costs dominate and
FDScanning wins across the board — the paper's own App. G observation
("HNSW candidates are close to the query => weak pruning") taken to the
extreme.  The paper's HNSW wins appear at 1M+ vectors; our IVF suite
(bench_query) carries the at-scale comparison in this container.
"""
from __future__ import annotations

from benchmarks.common import emit, fmt3
from repro.api import SearchSession
from repro.core.engine import make_schedule
from repro.core.methods import make_method
from repro.search.hnsw import HNSWIndex
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

K = 10
METHODS = ("FDScanning", "PDScanning", "PDScanning+", "ADSampling", "DADE",
           "DDCres")


def main():
    for ds_name, scale in (("sift", 0.03), ("gist", 0.08)):
        ds = load_dataset(ds_name, scale=scale)
        sched = make_schedule(ds.dim)
        # one shared graph (built with FDScanning; layout identical — App. A)
        base_m = make_method("FDScanning").fit(ds.X)
        idx = HNSWIndex(m=8, ef_construction=48).build(ds.X, method=base_m,
                                                       schedule=sched)
        gt, _ = ds.ground_truth(K)
        base_qps = None
        for name in METHODS:
            m = make_method(name).fit(ds.X)
            sess = SearchSession(m, "hnsw", idx)
            res = sess.search(ds.Q[:15], K, ef=64)
            rec = recall_at_k(res.ids, gt[:15])
            if base_qps is None:
                base_qps = res.qps
            emit(f"query_hnsw/{ds_name}/{name}", 1e6 / res.qps,
                 qps=f"{res.qps:.1f}", recall=fmt3(rec),
                 prune=fmt3(res.stats.pruning_ratio),
                 speedup_vs_fd=fmt3(res.qps / base_qps))


if __name__ == "__main__":
    main()
