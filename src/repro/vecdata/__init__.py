from repro.vecdata.synthetic import (DATASETS, DRIFT_SCENARIOS,  # noqa: F401
                                     VectorDataset, load_dataset,
                                     make_drift_scenario, make_ood_queries)
