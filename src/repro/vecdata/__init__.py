from repro.vecdata.synthetic import DATASETS, VectorDataset, load_dataset  # noqa: F401
