"""Synthetic dataset families mirroring Table IV of the paper.

This container has no network access, so each of the paper's 10 datasets is
represented by a synthetic family with matched DIMENSIONALITY, matched
distributional character (clustered image embeddings, heavy-tailed word
vectors, normalized LLM embeddings, OOD multimodal pairs, concatenated
token-block XUltra) and CPU-feasible cardinality.  Rankings / trends — the
paper's actual claims — are what we validate; absolute QPS is hardware-bound
anyway (we run the TPU story through the dry-run roofline instead).

Every dataset carries in-distribution queries; the multimodal families
(text2image, laion) also carry OOD queries drawn from a different modality
distribution, mirroring the paper's §V-B setup.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

# name -> (dim, n_base, n_query, category, ood)
DATASETS: dict = {
    "deep":       dict(dim=96,    n=200_000, nq=100, category="low",        ood=False),
    "glove":      dict(dim=100,   n=100_000, nq=100, category="low",        ood=False),
    "sift":       dict(dim=128,   n=100_000, nq=100, category="high",       ood=False),
    "text2image": dict(dim=200,   n=100_000, nq=100, category="high",       ood=True),
    "laion":      dict(dim=512,   n=50_000,  nq=100, category="high",       ood=True),
    "wikipedia":  dict(dim=768,   n=50_000,  nq=100, category="high",       ood=False),
    "gist":       dict(dim=960,   n=30_000,  nq=100, category="high",       ood=False),
    "openai":     dict(dim=1536,  n=20_000,  nq=100, category="ultra",      ood=False),
    "trevi":      dict(dim=4096,  n=10_000,  nq=50,  category="ultra",      ood=False),
    "xultra":     dict(dim=12288, n=4_000,   nq=25,  category="ultra",      ood=False),
}


@dataclass
class VectorDataset:
    name: str
    X: np.ndarray                 # (N, D) float32 base vectors
    Q: np.ndarray                 # (nq, D) in-distribution queries
    Q_ood: np.ndarray | None = None
    category: str = "high"
    _gt: dict = field(default_factory=dict)

    @property
    def dim(self):
        return self.X.shape[1]

    @property
    def n(self):
        return self.X.shape[0]

    def ground_truth(self, k: int, *, ood: bool = False) -> tuple:
        """Exact top-k ids + squared distances by brute force (cached)."""
        key = (k, ood)
        if key not in self._gt:
            Q = self.Q_ood if ood else self.Q
            d2 = (np.ascontiguousarray((self.X ** 2).sum(1))[None, :]
                  - 2.0 * Q @ self.X.T + (Q ** 2).sum(1)[:, None])
            idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
            row = np.arange(Q.shape[0])[:, None]
            order = np.argsort(d2[row, idx], axis=1)
            ids = idx[row, order]
            self._gt[key] = (ids, d2[row, ids])
        return self._gt[key]

    def normalized(self) -> "VectorDataset":
        """Unit-norm copy (for IP / cosine via the Eq. 8 transform)."""
        def nz(a):
            return a / np.maximum(np.linalg.norm(a, axis=1, keepdims=True), 1e-9)
        return VectorDataset(self.name + "-norm", nz(self.X), nz(self.Q),
                             None if self.Q_ood is None else nz(self.Q_ood),
                             self.category)


def _mixture(rng, n, dim, *, n_clusters, spectrum_alpha, spread=1.0, nonneg=False,
             heavy_tail=False):
    """Anisotropic Gaussian mixture with power-law eigen-spectrum — gives the
    PCA-based methods realistic variance concentration to exploit."""
    scales = (np.arange(1, dim + 1, dtype=np.float32) ** -spectrum_alpha)
    centers = rng.standard_normal((n_clusters, dim)).astype(np.float32) * scales * 3.0
    assign = rng.integers(0, n_clusters, n)
    Z = rng.standard_normal((n, dim)).astype(np.float32)
    if heavy_tail:
        Z *= rng.gamma(2.0, 1.0, (n, 1)).astype(np.float32)
    X = centers[assign] + Z * scales * spread
    if nonneg:
        X = np.abs(X)
    # random rotation so "original dim order" carries no free PCA signal
    return X


def _rotate(rng, X):
    d = X.shape[1]
    if d > 2048:      # a full Haar rotation is too costly; block-rotate
        blk = 512
        for lo in range(0, d, blk):
            hi = min(lo + blk, d)
            Q, _ = np.linalg.qr(rng.standard_normal((hi - lo, hi - lo)).astype(np.float32))
            X[:, lo:hi] = X[:, lo:hi] @ Q
        return X
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)).astype(np.float32))
    return X @ Q


_CACHE: dict = {}


def load_dataset(name: str, *, scale: float = 1.0, seed: int = 0) -> VectorDataset:
    """Generate (cached per-process) one of the 10 families."""
    key = (name, scale, seed)
    if key in _CACHE:
        return _CACHE[key]
    spec = DATASETS[name]
    # stable hash: builtin hash() is salted per process, which made every
    # process draw a DIFFERENT corpus (flaky thresholds, unpaired benchmarks)
    rng = np.random.default_rng(
        (zlib.crc32(name.encode()) + 7919 * seed) % (2 ** 31))
    n = max(1000, int(spec["n"] * scale))
    nq, dim = spec["nq"], spec["dim"]

    if name == "xultra":
        # concatenated token-block embeddings (paper §IV-B): 48 blocks of 256
        blk, nblk = 256, dim // 256
        vocab = _mixture(rng, 4096, blk, n_clusters=64, spectrum_alpha=0.6)
        tok = rng.integers(0, 4096, (n + nq, nblk))
        A = vocab[tok].reshape(n + nq, dim) + \
            0.1 * rng.standard_normal((n + nq, dim)).astype(np.float32)
        X, Q = A[:n], A[n:]
    else:
        alpha = {"deep": 0.35, "glove": 0.8, "sift": 0.5, "text2image": 0.6,
                 "laion": 0.7, "wikipedia": 0.7, "gist": 0.6, "openai": 0.8,
                 "trevi": 0.9}[name]
        A = _mixture(rng, n + nq, dim,
                     n_clusters=min(64, max(8, n // 2000)),
                     spectrum_alpha=alpha,
                     nonneg=(name in ("sift", "gist")),
                     heavy_tail=(name == "glove"))
        A = _rotate(rng, A)
        X, Q = A[:n], A[n:]

    Q_ood = None
    if spec["ood"]:
        # different modality: different spectrum + shifted cluster structure
        B = _mixture(rng, nq, dim, n_clusters=8, spectrum_alpha=0.2, spread=1.6)
        Q_ood = _rotate(np.random.default_rng(123), B).astype(np.float32)
        # keep scale comparable so thresholds stay in-range
        Q_ood *= (np.linalg.norm(X, axis=1).mean()
                  / max(np.linalg.norm(Q_ood, axis=1).mean(), 1e-9))
    if name == "openai":   # LLM embeddings ship normalized
        X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)
        Q /= np.maximum(np.linalg.norm(Q, axis=1, keepdims=True), 1e-9)

    ds = VectorDataset(name, np.ascontiguousarray(X, np.float32),
                       np.ascontiguousarray(Q, np.float32), Q_ood, spec["category"])
    _CACHE[key] = ds
    return ds


def make_ood_queries(X: np.ndarray, nq: int, *, severity: float = 1.0,
                     seed: int = 123) -> np.ndarray:
    """The OOD knob: queries whose per-direction energy profile is shifted
    away from the base corpus spectrum by ``severity``.

    In the principal basis of ``X``, in-distribution data has std
    ``sqrt(lam_i)`` along direction ``i``.  ``severity=0`` draws queries
    matching that profile (ID-like); ``severity=1`` draws from the REVERSED
    profile — energy concentrated in the lowest-variance directions, the
    modality-shift regime where lower-bound/estimator screening collapses
    (the paper's §V-B finding, and what drives the adaptive policy's
    fallback in bench_adaptive / tests).  Intermediate values interpolate
    geometrically.  Query norms are rescaled to the mean base-row norm so
    thresholds stay in-range (same convention as the built-in ``Q_ood``).
    """
    X = np.asarray(X, np.float32)
    rng = np.random.default_rng((zlib.crc32(b"oodknob") + 7919 * seed) % (2 ** 31))
    mu = X.mean(0)
    sub = X[rng.choice(X.shape[0], min(X.shape[0], 20_000), replace=False)] - mu
    cov = (sub.astype(np.float64).T @ sub) / max(sub.shape[0] - 1, 1)
    lam, V = np.linalg.eigh(cov)                  # ascending
    lam = np.maximum(lam[::-1], 1e-12)            # descending spectrum
    V = V[:, ::-1]
    std_id = np.sqrt(lam)
    w = (std_id ** (1.0 - severity)) * (std_id[::-1] ** severity)
    Z = rng.standard_normal((nq, X.shape[1]))
    Q = mu + (Z * w) @ V.T
    Q = Q.astype(np.float32)
    Q *= (np.linalg.norm(X, axis=1).mean()
          / max(np.linalg.norm(Q, axis=1).mean(), 1e-9))
    return np.ascontiguousarray(Q, np.float32)


#: Severity profiles of :func:`make_drift_scenario`.
DRIFT_SCENARIOS = ("gradual", "sudden", "recovering")


def make_drift_scenario(X: np.ndarray, nq: int, n_batches: int, *,
                        scenario: str = "sudden", severity: float = 1.0,
                        seed: int = 123) -> list:
    """A stream of query batches whose OOD severity follows a named drift
    profile — the guardrail layer's workload generator (DESIGN.md §9).

    Returns ``n_batches`` arrays of shape ``(nq, D)``; batch ``b`` is drawn
    by :func:`make_ood_queries` at that batch's severity (ID-like batches
    use severity 0.0 — the matched-spectrum draw — so every batch comes
    from the same generator and only the drift knob moves):

    ``"gradual"``     severity ramps linearly 0 -> ``severity`` over the
                      stream (slow modality creep; the sentinel EWMA should
                      cross its threshold mid-stream).
    ``"sudden"``      first third in-distribution, then a step to
                      ``severity`` (hard modality switch; breakers must
                      trip within a few batches).
    ``"recovering"``  in-distribution, a middle-third excursion at
                      ``severity``, then back (tests the half-open canary
                      re-promotion path).

    Each batch gets its own derived seed, so batches are independent draws
    and the whole stream is reproducible from ``seed``.
    """
    if scenario not in DRIFT_SCENARIOS:
        raise ValueError(
            f"scenario must be one of {DRIFT_SCENARIOS}, got {scenario!r}")
    if n_batches < 1:
        raise ValueError(f"n_batches must be >= 1, got {n_batches}")
    third = max(1, n_batches // 3)
    sev = np.zeros(n_batches)
    if scenario == "gradual":
        sev = np.linspace(0.0, 1.0, n_batches) * severity
    elif scenario == "sudden":
        sev[third:] = severity
    else:                                   # recovering
        sev[third:2 * third] = severity
    return [make_ood_queries(X, nq, severity=float(s), seed=seed + 1000 * b)
            for b, s in enumerate(sev)]


def recall_at_k(found_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Paper Eq. (1), averaged over queries."""
    k = gt_ids.shape[1]
    hits = sum(len(set(f[:k].tolist()) & set(g.tolist())) for f, g in zip(found_ids, gt_ids))
    return hits / (k * gt_ids.shape[0])
