"""jit'd public wrappers for the Pallas kernels: shape padding + fallbacks.

``interpret`` defaults to True when no TPU is present so the same call sites
work in this CPU container and on real hardware.  Setting
``REPRO_FORCE_INTERPRET=1`` forces interpret mode regardless of the platform
(CI runs the kernel parity tests under this flag as an explicit step).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dco_scan import dco_scan, dco_scan_grouped
from repro.kernels.pq_lookup import pq_lookup


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _resolve_interpret(interpret) -> bool:
    if os.environ.get("REPRO_FORCE_INTERPRET", "").lower() not in ("", "0", "false"):
        return True
    return (not _on_tpu()) if interpret is None else interpret


def _pad_to(a, axis, mult, value=0.0):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "block_d",
                                             "interpret"))
def dco_scan_op(x, q, tau, scales, nrows=None, *, block_n=256, block_q=128,
                block_d=128, interpret=None):
    """Padded staged-scan: arbitrary (N, Q, d1); returns (partial, keep,
    counts, dims) with partial/keep trimmed back to the logical shape.
    ``nrows`` (optional traced scalar) marks how many leading rows of ``x``
    are real — rows at or beyond it never keep and never count (the
    streaming engine passes the valid-row count of its last corpus block).
    Pad rows get partial=large, keep=0, and contribute nothing to ``counts``
    or ``dims``; dim blocks introduced by d1 padding have logical width 0 so
    they never inflate ``dims``."""
    interpret = _resolve_interpret(interpret)
    n, d1 = x.shape
    nq = q.shape[0]
    xp = _pad_to(_pad_to(x, 0, block_n), 1, block_d)
    qp = _pad_to(_pad_to(q, 0, block_q), 1, block_d)
    taup = _pad_to(tau, 0, block_q, value=-1.0)     # pad queries prune all
    nd = xp.shape[1] // block_d
    sc = scales
    if sc.shape[0] < nd:                            # extend schedule for padding
        sc = jnp.concatenate([sc, jnp.repeat(sc[-1:], nd - sc.shape[0])])
    w = np.clip(d1 - np.arange(nd) * block_d, 0, block_d).astype(np.float32)
    nr = jnp.reshape(jnp.asarray(n if nrows is None else nrows, jnp.int32), (1,))
    partial, keep, counts, dims = dco_scan(
        xp, qp, taup, sc[:nd], jnp.asarray(w), nr, block_n=block_n,
        block_q=block_q, block_d=block_d, interpret=interpret)
    return partial[:n, :nq], keep[:n, :nq], counts[:, :nq], dims[:, :nq]


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def dco_scan_grouped_op(x, q, tau, scales, widths, nrows=None, *, block_n=256,
                        block_q=128, interpret=None):
    """Padded PDX-layout staged scan: x (G, N, dg) dim-group-major corpus,
    q (G, Q, dg) queries split the same way, ``widths`` (G,) f32 the logical
    (unpadded) dim count of each group.  Pads N/Q to tile multiples and dg
    to a lane multiple with zeros (zero dims contribute nothing to the
    squared-distance partials, so values are unchanged).  Returns (partial,
    keep, counts, dims) trimmed like :func:`dco_scan_op`."""
    interpret = _resolve_interpret(interpret)
    _, n, dg = x.shape
    nq = q.shape[1]
    lane = 8 if interpret else 128                  # lane multiple only on TPU
    xp = _pad_to(_pad_to(x, 1, block_n), 2, lane)
    qp = _pad_to(_pad_to(q, 1, block_q), 2, lane)
    taup = _pad_to(tau, 0, block_q, value=-1.0)     # pad queries prune all
    nr = jnp.reshape(jnp.asarray(n if nrows is None else nrows, jnp.int32), (1,))
    partial, keep, counts, dims = dco_scan_grouped(
        xp, qp, taup, scales, widths.astype(jnp.float32), nr,
        block_n=block_n, block_q=block_q, interpret=interpret)
    return partial[:n, :nq], keep[:n, :nq], counts[:, :nq], dims[:, :nq]


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def pq_lookup_op(codes, lut, *, block_n=128, block_q=8, interpret=None):
    interpret = _resolve_interpret(interpret)
    n = codes.shape[0]
    nq = lut.shape[0]
    cp = _pad_to(codes.astype(jnp.int32), 0, block_n, value=0)
    lp = _pad_to(lut, 0, block_q)
    out = pq_lookup(cp, lp, block_n=block_n, block_q=block_q,
                    interpret=interpret)
    return out[:n, :nq]
