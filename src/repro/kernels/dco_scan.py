"""Pallas TPU kernel: staged DCO scan (stage-1 partial distances + screening).

TPU-native form of the paper's incremental dimension scanning (DESIGN.md §3):
the grid walks (query block, candidate block, dim block) with the dim axis
innermost; the ``partial`` output block — resident in VMEM across the whole
dim loop — carries the running partial distance, and after each dim block the
scaled-estimate-vs-tau test freezes pruned (row, query) pairs.  When an
entire (candidate x query) tile is dead, the next dim-block's matmul is
skipped via ``pl.when`` — the block-level early exit that replaces the
paper's per-vector ``break`` (compute is saved; the HBM->VMEM stream for the
skipped tile is the price of keeping the pipeline static, which is the right
trade on TPU where stage-1 is MXU-bound for d1 >= 128).

Two entry points share one kernel body:

  ``dco_scan``          row-major x (N, d1), dim blocks sliced on the fly —
                        the PR 2 layout;
  ``dco_scan_grouped``  PDX-style vertical x (G, N, dg) (DESIGN.md §8): each
                        dim GROUP is a contiguous (N, dg) plane, so the
                        per-dim-block HBM read is a unit-stride stream even
                        when candidates freeze between groups.

Outputs, per call:
  partial (N, Q) f32   running partial distances (frozen rows keep the value
                       at which they were pruned);
  keep    (N, Q) int8  1 iff the final scaled estimate still clears tau AND
                       the row index is < ``nrows`` (padding rows never keep);
  counts  (NB, Q) i32  per-candidate-block keep counts (NB = N / block_n) —
                       what the streaming engine (core.stream_engine) consumes
                       so no (N, Q) array ever has to leave the block loop;
  dims    (NB, Q) f32  dimensions actually entered per candidate block: each
                       dim block adds ``widths[di]`` for every still-alive
                       valid row — the measured early-exit telemetry behind
                       the facade's ``dims_read_mean`` stat.

Tile sizes: x tile (BN, BD), q tile (BQ, BD), accumulator (BN, BQ) — all
MXU-aligned multiples of (8, 128) for f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scales_ref, widths_ref, nrows_ref, x_ref, q_ref, tau_ref,
            out_ref, keep_ref, cnt_ref, dims_ref, *, nd_blocks: int,
            block_n: int):
    di = pl.program_id(2)
    row0 = pl.program_id(1) * block_n

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        dims_ref[...] = jnp.zeros_like(dims_ref)

    tau = tau_ref[...][None, :]                            # (1, BQ)
    prev_scale = scales_ref[jnp.maximum(di - 1, 0)]
    alive = out_ref[...] * prev_scale <= tau               # frozen rows stay dead
    row = row0 + jax.lax.broadcasted_iota(jnp.int32, alive.shape, 0)
    # dims telemetry: every alive valid row 'reads' this dim block's logical
    # width (0 for shape-padding dim blocks), whether or not the tile-level
    # skip below saves the matmul — per-row exit is what the stat measures
    entering = (alive & (row < nrows_ref[0])).astype(jnp.float32)
    dims_ref[...] += entering.sum(0, keepdims=True) * widths_ref[di]

    @pl.when(jnp.any(alive))
    def _compute():
        xb = x_ref[...]                                    # (BN, BD) / (1, BN, dg)
        xb = xb.reshape(xb.shape[-2], xb.shape[-1])
        qb = q_ref[...]                                    # (BQ, BD) / (1, BQ, dg)
        qb = qb.reshape(qb.shape[-2], qb.shape[-1])
        contrib = ((xb * xb).sum(1, keepdims=True)
                   - 2.0 * jax.lax.dot_general(
                       xb, qb, (((1,), (1,)), ((), ())),
                       preferred_element_type=jnp.float32)
                   + (qb * qb).sum(1, keepdims=True).T)
        out_ref[...] = jnp.where(alive, out_ref[...] + jnp.maximum(contrib, 0.0),
                                 out_ref[...])

    @pl.when(di == nd_blocks - 1)
    def _finish():
        est = out_ref[...] * scales_ref[di]
        keep = alive & (est <= tau) & (row < nrows_ref[0])
        keep_ref[...] = keep.astype(jnp.int8)
        cnt_ref[...] = keep.astype(jnp.int32).sum(0, keepdims=True)


def _out_shapes(n, nq, nnb):
    return [
        jax.ShapeDtypeStruct((n, nq), jnp.float32),
        jax.ShapeDtypeStruct((n, nq), jnp.int8),
        jax.ShapeDtypeStruct((nnb, nq), jnp.int32),
        jax.ShapeDtypeStruct((nnb, nq), jnp.float32),
    ]


def _out_specs(block_n, block_q):
    return [
        pl.BlockSpec((block_n, block_q), lambda qi, ni, di: (ni, qi)),
        pl.BlockSpec((block_n, block_q), lambda qi, ni, di: (ni, qi)),
        pl.BlockSpec((1, block_q), lambda qi, ni, di: (ni, qi)),
        pl.BlockSpec((1, block_q), lambda qi, ni, di: (ni, qi)),
    ]


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "block_d",
                                             "interpret"))
def dco_scan(x, q, tau, scales, widths, nrows, *, block_n: int = 256,
             block_q: int = 128, block_d: int = 128, interpret: bool = False):
    """x (N, d1) rotated leading dims; q (Q, d1) rotated queries;
    tau (Q,) squared thresholds; scales (n_dblocks,) estimate multipliers;
    widths (n_dblocks,) f32 logical dims per dim block (0 for padding
    blocks); nrows (1,) i32 count of valid (non-padding) leading rows of x.
    Returns (partial (N, Q) f32, keep (N, Q) int8, counts (N/block_n, Q) i32,
    dims (N/block_n, Q) f32).  N, Q, d1 must be tile multiples —
    ``kernels.ops.dco_scan_op`` pads arbitrary shapes."""
    n, d1 = x.shape
    nq = q.shape[0]
    nd = pl.cdiv(d1, block_d)
    nnb = pl.cdiv(n, block_n)
    grid = (pl.cdiv(nq, block_q), nnb, nd)
    kernel = functools.partial(_kernel, nd_blocks=nd, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((scales.shape[0],), lambda qi, ni, di: (0,)),
            pl.BlockSpec((widths.shape[0],), lambda qi, ni, di: (0,)),
            pl.BlockSpec((1,), lambda qi, ni, di: (0,)),
            pl.BlockSpec((block_n, block_d), lambda qi, ni, di: (ni, di)),
            pl.BlockSpec((block_q, block_d), lambda qi, ni, di: (qi, di)),
            pl.BlockSpec((block_q,), lambda qi, ni, di: (qi,)),
        ],
        out_specs=_out_specs(block_n, block_q),
        out_shape=_out_shapes(n, nq, nnb),
        interpret=interpret,
    )(scales, widths, nrows, x, q, tau)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def dco_scan_grouped(x, q, tau, scales, widths, nrows, *, block_n: int = 256,
                     block_q: int = 128, interpret: bool = False):
    """PDX-layout staged scan: x (G, N, dg) vertical corpus (dim group
    major, each group a contiguous (N, dg) plane), q (G, Q, dg) the queries
    split the same way.  The grid's innermost axis walks GROUPS, so the
    per-group freeze/skip semantics are exactly ``dco_scan``'s per-dim-block
    semantics, but the HBM stream for each group is unit-stride (DESIGN.md
    §8).  Same outputs as :func:`dco_scan`; N, Q, dg must be tile multiples
    (``kernels.ops.dco_scan_grouped_op`` pads)."""
    ng, n, dg = x.shape
    nq = q.shape[1]
    nnb = pl.cdiv(n, block_n)
    grid = (pl.cdiv(nq, block_q), nnb, ng)
    kernel = functools.partial(_kernel, nd_blocks=ng, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((scales.shape[0],), lambda qi, ni, di: (0,)),
            pl.BlockSpec((widths.shape[0],), lambda qi, ni, di: (0,)),
            pl.BlockSpec((1,), lambda qi, ni, di: (0,)),
            pl.BlockSpec((1, block_n, dg), lambda qi, ni, di: (di, ni, 0)),
            pl.BlockSpec((1, block_q, dg), lambda qi, ni, di: (di, qi, 0)),
            pl.BlockSpec((block_q,), lambda qi, ni, di: (qi,)),
        ],
        out_specs=_out_specs(block_n, block_q),
        out_shape=_out_shapes(n, nq, nnb),
        interpret=interpret,
    )(scales, widths, nrows, x, q, tau)
