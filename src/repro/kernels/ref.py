"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def dco_scan_ref(x, q, tau, scales, block_d: int):
    """Incremental staged DCO scan, reference semantics.

    x (N, d1), q (Q, d1); scales (n_dblocks,) per-stage estimate multipliers
    (1.0 for lower-bound methods, D/d or eigen-mass factors for estimators).
    A (row, query) pair 'freezes' at the first dim-block where its scaled
    partial exceeds tau[query]; its partial output keeps the frozen value and
    keep=0.  Survivors end with the full d1-dim partial and keep=1.

    Returns (partial (N, Q) f32, keep (N, Q) int8).
    """
    n, d1 = x.shape
    nq = q.shape[0]
    nblk = (d1 + block_d - 1) // block_d
    acc = jnp.zeros((n, nq), jnp.float32)
    alive = jnp.ones((n, nq), bool)
    for b in range(nblk):
        lo, hi = b * block_d, min((b + 1) * block_d, d1)
        xb, qb = x[:, lo:hi], q[:, lo:hi]
        contrib = ((xb ** 2).sum(1)[:, None] - 2.0 * xb @ qb.T
                   + (qb ** 2).sum(1)[None, :])
        acc = jnp.where(alive, acc + jnp.maximum(contrib, 0.0), acc)
        est = acc * scales[b]
        alive = alive & (est <= tau[None, :])
    return acc, alive.astype(jnp.int8)


def dco_scan_dims_ref(x, q, tau, scales, block_d: int, block_n: int,
                      nrows=None):
    """Oracle for the kernel's per-(row-block, query) ``dims`` output.

    Mirrors the kernel's gating exactly: a (row, query) pair 'enters' dim
    block b iff its running partial scaled by the PREVIOUS block's scale is
    still <= tau (so at b=0 a pair enters iff tau >= 0) AND the row index is
    below ``nrows``; each entering pair charges the block's logical width.

    Returns dims (ceil(N/block_n), Q) f32.
    """
    n, d1 = x.shape
    nq = q.shape[0]
    nblk = (d1 + block_d - 1) // block_d
    nb = -(-n // block_n)
    valid = (jnp.arange(n) < (n if nrows is None else nrows))[:, None]
    acc = jnp.zeros((n, nq), jnp.float32)
    dims = jnp.zeros((nb, nq), jnp.float32)
    for b in range(nblk):
        lo, hi = b * block_d, min((b + 1) * block_d, d1)
        prev = scales[max(b - 1, 0)] if b > 0 else 1.0
        alive = (acc * (prev if b > 0 else 0.0)) <= tau[None, :]
        entering = (alive & valid).astype(jnp.float32)
        ep = jnp.pad(entering, ((0, nb * block_n - n), (0, 0)))
        dims = dims + ep.reshape(nb, block_n, nq).sum(1) * float(hi - lo)
        xb, qb = x[:, lo:hi], q[:, lo:hi]
        contrib = ((xb ** 2).sum(1)[:, None] - 2.0 * xb @ qb.T
                   + (qb ** 2).sum(1)[None, :])
        acc = jnp.where(alive, acc + jnp.maximum(contrib, 0.0), acc)
    return dims


def block_keep_counts_ref(keep, block_n: int):
    """Oracle for the kernel's per-candidate-block counts output: sum the
    (N, Q) keep mask over row blocks of ``block_n`` (pad rows count 0)."""
    n, nq = keep.shape
    nb = -(-n // block_n)
    kp = jnp.pad(keep.astype(jnp.int32), ((0, nb * block_n - n), (0, 0)))
    return kp.reshape(nb, block_n, nq).sum(1)


def pq_lookup_ref(codes, lut):
    """codes (N, M) int32, lut (Q, M, K) f32 -> adist (N, Q) f32."""
    # gather formulation: adist[n, q] = sum_m lut[q, m, codes[n, m]]
    n, m = codes.shape
    g = lut[:, jnp.arange(m)[None, :], codes]       # (Q, N, M)
    return jnp.moveaxis(g.sum(-1), 0, 1)            # (N, Q)


def make_dco_scales(kind: str, d1: int, block_d: int, D: int, *,
                    eps0: float = 2.1, mass=None, eps_d=None, theta: float = 1.0):
    """Per-dim-block estimate multipliers matching core.methods decisions."""
    nblk = (d1 + block_d - 1) // block_d
    ds = np.minimum((np.arange(1, nblk + 1)) * block_d, d1).astype(np.float64)
    if kind in ("lb", "fdscan"):
        s = np.ones(nblk)
    elif kind == "adsampling":
        s = (D / ds) / (1.0 + eps0 / np.sqrt(ds)) ** 2
    elif kind == "dade":
        m = np.asarray(mass, np.float64)[np.minimum(ds.astype(int) - 1, len(mass) - 1)]
        e = np.asarray(eps_d, np.float64)[np.minimum(ds.astype(int) - 1, len(eps_d) - 1)]
        s = 1.0 / (np.maximum(m, 1e-9) * (1.0 + e) ** 2)
    elif kind == "ratio":
        s = np.full(nblk, 1.0 / max(theta, 1e-9))
    else:
        raise ValueError(kind)
    return jnp.asarray(s, jnp.float32)
