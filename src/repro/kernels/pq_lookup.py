"""Pallas TPU kernel: PQ asymmetric-distance scan (DDCopq's screening pass).

On CPU-SIMD / GPU this is a per-lane LUT gather (`lut[m, codes[n, m]]`) — a
shuffle-heavy pattern with no TPU analogue.  The TPU-native rewrite
(DESIGN.md §3): expand the uint8/uint16 codes of a candidate tile into a
one-hot tensor and contract it with the query LUT on the MXU:

    adist[n, q] = onehot(codes)[n, m, k] * lut[q, m, k]   (sum over m, k)

i.e. one (BN, M*K) @ (M*K, BQ) matmul per tile — gathers become matmuls,
which is exactly how embedding lookups are lowered on TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...]                                  # (BN, M) int32
    lut = lut_ref[...]                                      # (BQ, M, K) f32
    bn, m = codes.shape
    bq, _, k = lut.shape
    onehot = (codes[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
              ).astype(jnp.float32)                         # (BN, M, K)
    out_ref[...] = jax.lax.dot_general(
        onehot.reshape(bn, m * k), lut.reshape(bq, m * k),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def pq_lookup(codes, lut, *, block_n: int = 128, block_q: int = 8,
              interpret: bool = False):
    """codes (N, M) int32; lut (Q, M, K) f32 -> adist (N, Q) f32.
    N, Q must be tile multiples (see kernels.ops.pq_lookup_op for padding)."""
    n, m = codes.shape
    nq, _, k = lut.shape
    grid = (pl.cdiv(nq, block_q), pl.cdiv(n, block_n))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda qi, ni: (ni, 0)),
            pl.BlockSpec((block_q, m, k), lambda qi, ni: (qi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_q), lambda qi, ni: (ni, qi)),
        out_shape=jax.ShapeDtypeStruct((n, nq), jnp.float32),
        interpret=interpret,
    )(codes, lut)
