"""Capacity-based Mixture-of-Experts with expert parallelism (EP).

Distribution scheme (DESIGN.md §4): activations reach the FFN replicated
over the TP ("model") axis and sharded over the DP axes.  Each (data, model)
device therefore already holds its token shard, and we assign experts to the
"model" axis: device (d, m) runs experts [m·E/tp, (m+1)·E/tp) over data
shard d's tokens with a capacity-bounded gather, and a psum over "model"
reassembles the gate-weighted combine.  No all-to-all is needed — the psum
is the same collective TP would issue after a dense FFN.

Expert weights are additionally FSDP-sharded on d_model over the DP axes;
shard_map receives them sharded and all-gathers per layer (standard FSDP
unshard, transient full-layer copy in VMEM/HBM).

Token dropping: per-expert capacity C = ceil(T_local·top_k/E · cf).  The
oracle test checks equivalence to dense routing when C >= T_local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import CDTYPE, dense_init


def init_moe(key, cfg):
    mc = cfg.moe
    d, E, f = cfg.d_model, mc.n_experts, mc.d_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, E, scale=0.02),
        "wg": jax.random.normal(ks[1], (E, d, f), jnp.float32) / jnp.sqrt(d),
        "wu": jax.random.normal(ks[2], (E, d, f), jnp.float32) / jnp.sqrt(d),
        "wd": jax.random.normal(ks[3], (E, f, d), jnp.float32) / jnp.sqrt(f),
    }
    if mc.n_shared:
        k1, k2, k3 = jax.random.split(ks[0], 3)
        fs = mc.n_shared * f
        p["shared"] = {"wg": dense_init(k1, d, fs), "wu": dense_init(k2, d, fs),
                       "wd": dense_init(k3, fs, d)}
    return p


def _expert_compute(xg, wg, wu, wd):
    """xg (E, C, D) -> (E, C, D) through per-expert SwiGLU."""
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg,
                                preferred_element_type=jnp.float32))
         * jnp.einsum("ecd,edf->ecf", xg, wu,
                      preferred_element_type=jnp.float32))
    return jnp.einsum("ecf,efd->ecd", h.astype(xg.dtype), wd,
                      preferred_element_type=jnp.float32)


def _route_and_compute(x_flat, router, wg, wu, wd, *, top_k, capacity,
                       e_offset, n_local):
    """Local MoE over T_local tokens and n_local experts.
    Returns (out (T, D) f32 partial sum, router probs (T, E) f32)."""
    T, D = x_flat.shape
    E = router.shape[1]
    logits = (x_flat.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)                      # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # (T, k)
    # normalized top-k gates scattered back to (T, E)
    gmat = jnp.zeros((T, E), jnp.float32)
    gmat = gmat.at[jnp.arange(T)[:, None], gate_idx].set(
        gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9))
    # local expert slice -> expert-choice top-C tokens
    loc = jax.lax.dynamic_slice_in_dim(gmat, e_offset, n_local, axis=1).T  # (El, T)
    score = jnp.where(loc > 0, loc, -jnp.inf)
    top_val, tok_idx = jax.lax.top_k(score, min(capacity, T))              # (El, C)
    alive = jnp.isfinite(top_val)
    gates = jnp.where(alive, top_val, 0.0)
    xg = x_flat[tok_idx.reshape(-1)].reshape(n_local, -1, D).astype(CDTYPE)
    y = _expert_compute(xg, wg.astype(CDTYPE), wu.astype(CDTYPE),
                        wd.astype(CDTYPE))                                  # (El,C,D) f32
    y = y * gates[..., None]
    out = jnp.zeros((T, D), jnp.float32)
    out = out.at[tok_idx.reshape(-1)].add(y.reshape(-1, D))
    return out, probs


def _aux_loss(probs, gmat_mean_assign=None):
    """Switch-style load-balance loss: E * sum_e mean(prob_e) * mean(assign_e).
    We use the soft version E * sum mean(prob)^2 which has the same optimum
    and avoids carrying assignments across shards."""
    me = probs.mean(0)
    return probs.shape[1] * jnp.sum(me * me)


def moe_forward(params, cfg, x, *, mesh=None, dp_axes=("data",),
                tp_axis="model", psum_dtype=None):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``psum_dtype=bf16`` (or env REPRO_MOE_PSUM_BF16=1) compresses the EP
    combine collective — EXPERIMENTS.md §Perf cell B."""
    import os as _os
    if psum_dtype is None and _os.environ.get("REPRO_MOE_PSUM_BF16"):
        psum_dtype = jnp.bfloat16
    mc = cfg.moe
    B, S, D = x.shape
    E = mc.n_experts

    dp_size = 1
    if mesh is not None and tp_axis in getattr(mesh, "axis_names", ()):
        for a in dp_axes:
            dp_size *= mesh.shape[a]
    # fall back to the local (replicated) path when the batch cannot shard
    # over DP (e.g. batch=1 long-context decode) or experts don't divide TP
    unshardable = (mesh is None
                   or tp_axis not in getattr(mesh, "axis_names", ())
                   or B % dp_size != 0
                   or E % mesh.shape[tp_axis] != 0)

    if unshardable:
        x_flat = x.reshape(-1, D)
        T = x_flat.shape[0]
        if T <= 32:
            # DROPLESS decode path: tiny token counts must not compete for
            # expert capacity (a decode step's routing would otherwise depend
            # on unrelated requests in the batch).  Per-slot expert-weight
            # gather — T*top_k gathers of (D, F) weights.
            logits = x_flat.astype(jnp.float32) @ params["router"].astype(jnp.float32)
            probs = jax.nn.softmax(logits, -1)
            vals, idx = jax.lax.top_k(probs, mc.top_k)
            vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
            out = jnp.zeros((T, D), jnp.float32)
            xc = x_flat.astype(CDTYPE)
            for j in range(mc.top_k):
                wg = params["wg"][idx[:, j]].astype(CDTYPE)   # (T, D, F)
                wu = params["wu"][idx[:, j]].astype(CDTYPE)
                wd = params["wd"][idx[:, j]].astype(CDTYPE)
                h = (jax.nn.silu(jnp.einsum("td,tdf->tf", xc, wg))
                     * jnp.einsum("td,tdf->tf", xc, wu))
                y = jnp.einsum("tf,tfd->td", h, wd,
                               preferred_element_type=jnp.float32)
                out = out + vals[:, j, None] * y
            aux = _aux_loss(probs)
        else:
            cap = max(1, int(T * mc.top_k / E * mc.capacity_factor))
            out, probs = _route_and_compute(
                x_flat, params["router"], params["wg"], params["wu"],
                params["wd"], top_k=mc.top_k, capacity=cap, e_offset=0,
                n_local=E)
            aux = _aux_loss(probs)
        out = out.reshape(B, S, D)
    else:
        tp = mesh.shape[tp_axis]
        n_local = E // tp
        dp = 1
        for a in dp_axes:
            dp *= mesh.shape[a]
        t_local = (B // dp) * S
        cap = max(1, int(t_local * mc.top_k / E * mc.capacity_factor))

        def local_fn(xl, router, wg, wu, wd):
            # FSDP unshard of this layer's experts (all-gather over dp axes)
            for a in dp_axes:
                wg = jax.lax.all_gather(wg, a, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, a, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, a, axis=2, tiled=True)
            xf = xl.reshape(-1, D)
            m_idx = jax.lax.axis_index(tp_axis)
            out, probs = _route_and_compute(
                xf, router, wg, wu, wd, top_k=mc.top_k, capacity=cap,
                e_offset=m_idx * n_local, n_local=n_local)
            # gradient/activation compression: the EP combine is a sum of
            # <= top_k + shared bf16-computed contributions — psum in bf16
            # halves the TP collective bytes (EXPERIMENTS.md §Perf B)
            if psum_dtype is not None:
                out = jax.lax.psum(out.astype(psum_dtype), tp_axis)
            else:
                out = jax.lax.psum(out, tp_axis)
            aux = jax.lax.pmean(_aux_loss(probs), dp_axes)
            return out.reshape(xl.shape).astype(
                psum_dtype or out.dtype), aux

        from jax.experimental import shard_map
        local_fn_sm = shard_map.shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(dp_axes, None, None), P(None, None),
                      P(tp_axis, dp_axes, None), P(tp_axis, dp_axes, None),
                      P(tp_axis, None, dp_axes)),
            out_specs=(P(dp_axes, None, None), P()),
            check_rep=False,
        )
        out, aux = local_fn_sm(x, params["router"], params["wg"],
                               params["wu"], params["wd"])

    out = out.astype(x.dtype)
    if mc.n_shared:
        sp = params["shared"]
        xc = x.astype(CDTYPE)
        h = jax.nn.silu(xc @ sp["wg"].astype(CDTYPE)) * (xc @ sp["wu"].astype(CDTYPE))
        out = out + (h @ sp["wd"].astype(CDTYPE)).astype(x.dtype)
    return out, aux * mc.aux_loss_weight
