"""Shared model layers: norms, RoPE, blockwise attention, MLPs.

Conventions:
  * params are plain nested dicts of jnp arrays (no flax in this container);
  * compute dtype bf16, accumulation/softmax f32;
  * attention is blockwise (online softmax over KV chunks) so 32k-prefill
    activations never materialize an (S x S) score matrix;
  * every init function takes an explicit PRNG key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

CDTYPE = jnp.bfloat16    # compute dtype


def dense_init(key, d_in, d_out, scale=None):
    s = (1.0 / np.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s)


def rms_norm(x, gamma=None, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps)
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    return y.astype(x.dtype)


def nonparam_layer_norm(x, eps=1e-6):
    """OLMo-style non-parametric LayerNorm (no gain/bias)."""
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(cfg):
    if cfg.nonparam_ln:
        return (lambda key, d: None), (lambda p, x: nonparam_layer_norm(x))
    return (lambda key, d: jnp.ones((d,), jnp.float32)), (lambda p, x: rms_norm(x, p))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta):
    """x (..., S, H, hd); positions (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # (...,S,hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]   # (...,S,1,hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (training / prefill)
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, kind, prefix_len):
    if kind == "full":
        return jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    m = q_pos[:, None] >= kv_pos[None, :]
    if kind == "prefix":   # bidirectional over the leading prefix tokens
        m = m | (kv_pos[None, :] < prefix_len)
    return m


def blockwise_attention(q, k, v, *, kind="causal", prefix_len=0, q_offset=0,
                        block_q=512, block_kv=1024, scale=None):
    """q (B, Sq, H, hd); k/v (B, Skv, Hkv, hd).  Online-softmax over KV
    chunks; memory is O(block_q * block_kv) per (batch, head)."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)

    def _pick(S, target):
        """largest divisor of S that is <= target (static shapes)."""
        for b in range(min(target, S), 0, -1):
            if S % b == 0:
                return b
        return S

    block_q = _pick(Sq, block_q)
    block_kv = _pick(Skv, block_kv)
    nq, nk = Sq // block_q, Skv // block_kv

    qg = q.reshape(B, nq, block_q, Hkv, G, hd)
    kg = k.reshape(B, nk, block_kv, Hkv, hd)
    vg = v.reshape(B, nk, block_kv, Hkv, hd)

    def q_chunk(iq):
        qc = qg[:, iq]                                   # (B, bq, Hkv, G, hd)
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)

        def kv_step(carry, ik):
            m_run, l_run, acc = carry
            kc, vc = kg[:, ik], vg[:, ik]                # (B, bk, Hkv, hd)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            kv_pos = ik * block_kv + jnp.arange(block_kv)
            msk = _mask(q_pos, kv_pos, kind, prefix_len)
            s = jnp.where(msk[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m_run, s.max(-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(msk[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0)
            l_new = l_run * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, Hkv, G, block_q), -jnp.inf, jnp.float32),
                jnp.zeros((B, Hkv, G, block_q), jnp.float32),
                jnp.zeros((B, Hkv, G, block_q, hd), jnp.float32))
        # checkpoint the kv step as well: its backward residuals become the
        # small (m, l, acc) carries instead of stacked (bq x bk) score tiles
        (m_f, l_f, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False), init, jnp.arange(nk))
        out = acc / jnp.maximum(l_f[..., None], 1e-20)
        return out                                        # (B, Hkv, G, bq, hd)

    # checkpoint each q-chunk: the backward recomputes its KV scan instead of
    # stacking (S x S) attention probabilities as residuals (flash-attention
    # backward semantics; verified against the dry-run HLO residual shapes)
    outs = jax.lax.map(jax.checkpoint(q_chunk, prevent_cse=False),
                       jnp.arange(nq))                    # (nq, B, Hkv, G, bq, hd)
    out = jnp.moveaxis(outs, 0, 3)                        # (B, Hkv, G, nq, bq, hd)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, scale=None):
    """Single-step decode: q (B, 1, H, hd); caches (B, Smax, Hkv, hd);
    cur_len (B,) or scalar valid lengths (the new token is at cur_len-1)."""
    B, _, H, hd = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cur_len), (B,))[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {"wg": dense_init(ks[0], d, f), "wu": dense_init(ks[1], d, f),
                "wd": dense_init(ks[2], f, d)}
    return {"w1": dense_init(ks[0], d, f), "w2": dense_init(ks[1], f, d)}


def mlp(params, cfg, x):
    xc = x.astype(CDTYPE)
    if cfg.act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(xc @ params["wg"].astype(CDTYPE)) * (xc @ params["wu"].astype(CDTYPE))
        return (h @ params["wd"].astype(CDTYPE)).astype(x.dtype)
    h = jax.nn.gelu(xc @ params["w1"].astype(CDTYPE))
    return (h @ params["w2"].astype(CDTYPE)).astype(x.dtype)
