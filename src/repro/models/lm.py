"""Model assembly: every assigned architecture family behind one ModelApi.

Families:
  dense  — qwen3-32b/4b, olmo-1b, starcoder2-7b
  moe    — deepseek-v2/v3 (MLA attention + shared/routed experts + MTP)
  ssm    — mamba2-130m
  hybrid — jamba (1 attn : 7 mamba interleave, MoE every other layer)
  encdec — seamless-m4t (stubbed audio-frame encoder input)
  vlm    — paligemma (stubbed patch-embedding prefix, prefix-LM mask)

Layers are stacked and scanned (jax.lax.scan) to bound HLO size when
lowering 61-layer models against 512 devices.  The LM-head cross entropy is
computed in sequence chunks so (B, S, V) logits never materialize.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.layers import CDTYPE


@dataclass
class ModelApi:
    cfg: ArchConfig
    init: Callable                    # (key) -> params
    loss: Callable                    # (params, batch) -> (loss, metrics)
    prefill: Callable                 # (params, batch) -> (logits, cache)
    decode_step: Callable             # (params, cache, token, cur_len) -> (logits, cache)
    init_cache: Callable              # (batch, max_len) -> cache


def make_constrainer(mesh, dp_axes):
    """Activation sharding constraint: batch rows over the DP axes.

    GSPMD drops the batch sharding at the embedding gather + scan boundary
    (verified in the dry-run HLO: global-batch `pred` masks inside the layer
    loop), so every block body re-pins its input — the standard MaxText-style
    activation constraint.  No-op when the dim doesn't divide or mesh is None.
    """
    if mesh is None:
        return lambda x: x
    from jax.sharding import NamedSharding
    import numpy as np
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))

    def constrain(x):
        if x.ndim == 0 or x.shape[0] % dp_size != 0:
            return x
        spec = P(dp_axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _embed_init(key, cfg):
    return jax.random.normal(key, (cfg.vocab_padded, cfg.d_model),
                             jnp.float32) * 0.02


def _head(params, cfg, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h.astype(CDTYPE) @ w.astype(CDTYPE)).astype(jnp.float32)


def chunked_ce(params, cfg, h, targets, mask, *, chunk=512, extra_h=None):
    """Cross entropy over padded vocab without materializing full logits.
    h (B, S, D) f; targets (B, S) int32; mask (B, S) f32."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, D)
    tc = targets.reshape(B, nc, chunk)
    mc = mask.reshape(B, nc, chunk)

    def body(carry, ins):
        hs, ts, ms = ins                                   # (B,c,D),(B,c),(B,c)
        logits = _head(params, cfg, hs)                    # (B,c,Vp) f32
        logits = jnp.where(jnp.arange(cfg.vocab_padded)[None, None, :] < cfg.vocab,
                           logits, -1e30)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, ts[..., None], -1)[..., 0]
        return carry + ((lse - gold) * ms).sum(), None

    tot, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(tc, 1, 0), jnp.moveaxis(mc, 1, 0)))
    return tot / jnp.maximum(mask.sum(), 1.0)


def _norm_fns(cfg):
    init_n, apply_n = L.make_norm(cfg)
    return init_n, apply_n


# ---------------------------------------------------------------------------
# block definitions (one per family flavour)
# ---------------------------------------------------------------------------


def _init_dense_block(key, cfg):
    init_n, _ = _norm_fns(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"attn": A.init_attention(k1, cfg), "mlp": L.init_mlp(k2, cfg),
            "n1": init_n(k3, cfg.d_model), "n2": init_n(k4, cfg.d_model)}


def _dense_block(p, cfg, h, *, kind="causal", prefix_len=0):
    _, apply_n = _norm_fns(cfg)
    h = h + A.attention_forward(p["attn"], cfg, apply_n(p["n1"], h),
                                kind=kind, prefix_len=prefix_len)
    h = h + L.mlp(p["mlp"], cfg, apply_n(p["n2"], h))
    return h


def _dense_block_decode(p, cfg, h, cache, cur_len):
    _, apply_n = _norm_fns(cfg)
    a, cache = A.attention_decode(p["attn"], cfg, apply_n(p["n1"], h),
                                  cache, cur_len)
    h = h + a
    h = h + L.mlp(p["mlp"], cfg, apply_n(p["n2"], h))
    return h, cache


def _init_mla_block(key, cfg, *, use_moe):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"attn": MLA.init_mla(k1, cfg),
         "n1": jnp.ones((cfg.d_model,), jnp.float32),
         "n2": jnp.ones((cfg.d_model,), jnp.float32)}
    if use_moe:
        p["moe"] = MOE.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def _mla_block(p, cfg, h, *, mesh, dp_axes):
    a, kv = MLA.mla_forward(p["attn"], cfg, L.rms_norm(h, p["n1"]))
    h = h + a
    if "moe" in p:
        f, aux = MOE.moe_forward(p["moe"], cfg, L.rms_norm(h, p["n2"]),
                                 mesh=mesh, dp_axes=dp_axes)
    else:
        f, aux = L.mlp(p["mlp"], cfg, L.rms_norm(h, p["n2"])), 0.0
    return h + f, aux, kv


def _mla_block_decode(p, cfg, h, cache, cur_len, *, mesh, dp_axes):
    a, cache = MLA.mla_decode(p["attn"], cfg, L.rms_norm(h, p["n1"]), cache,
                              cur_len)
    h = h + a
    if "moe" in p:
        f, _ = MOE.moe_forward(p["moe"], cfg, L.rms_norm(h, p["n2"]),
                               mesh=mesh, dp_axes=dp_axes)
    else:
        f = L.mlp(p["mlp"], cfg, L.rms_norm(h, p["n2"]))
    return h + f, cache


def _init_mamba_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"mixer": M.init_mamba(k1, cfg),
            "n1": jnp.ones((cfg.d_model,), jnp.float32)}


def _mamba_block(p, cfg, h, *, state=None, return_state=False):
    if return_state:
        y, st = M.mamba_forward(p["mixer"], cfg, L.rms_norm(h, p["n1"]),
                                init_state=None, return_state=True)
        return h + y, st
    return h + M.mamba_forward(p["mixer"], cfg, L.rms_norm(h, p["n1"]))


def _mamba_block_decode(p, cfg, h, state):
    y, st = M.mamba_decode(p["mixer"], cfg, L.rms_norm(h, p["n1"]), state)
    return h + y, st


# ---------------------------------------------------------------------------
# family: dense decoder (also vlm via prefix mask)
# ---------------------------------------------------------------------------


def build_dense(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                remat: str = "block") -> ModelApi:
    prefix = cfg.prefix_len
    _c = make_constrainer(mesh, dp_axes)

    def init(key):
        ks = jax.random.split(key, cfg.n_layers + 3)
        layers = jax.vmap(lambda k: _init_dense_block(k, cfg))(
            jnp.stack(ks[: cfg.n_layers]))
        p = {"embed": _embed_init(ks[-1], cfg), "layers": layers,
             "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_init(ks[-2], cfg.d_model, cfg.vocab_padded)
        return p

    def backbone(params, h, *, kind="causal"):
        body = (lambda hh, lp: (_c(_dense_block(lp, cfg, hh, kind=kind,
                                                prefix_len=prefix)), None))
        if remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, h, params["layers"])
        return L.rms_norm(h, params["final_norm"]) if not cfg.nonparam_ln \
            else L.nonparam_layer_norm(h)

    def _inputs_to_h(params, batch):
        tok = batch["tokens"]
        h = params["embed"][tok].astype(jnp.bfloat16)
        if prefix and "patches" in batch:
            h = jnp.concatenate([batch["patches"].astype(h.dtype), h], 1)
        return _c(h)

    def loss(params, batch):
        h = _inputs_to_h(params, batch)
        kind = "prefix" if prefix else "causal"
        h = backbone(params, h, kind=kind)
        tok = batch["tokens"]
        tgt = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tok[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        if prefix and "patches" in batch:
            h = h[:, prefix:]
        ce = chunked_ce(params, cfg, h, tgt, mask)
        return ce, {"ce": ce}

    def prefill(params, batch):
        h = _inputs_to_h(params, batch)
        kind = "prefix" if prefix else "causal"
        S = h.shape[1]
        caches = []

        def body(hh, lp):
            a, kv = A.attention_forward(
                lp["attn"], cfg,
                (L.nonparam_layer_norm(hh) if cfg.nonparam_ln
                 else L.rms_norm(hh, lp["n1"])),
                kind=kind, prefix_len=prefix, return_kv=True)
            hh = hh + a
            hh = hh + L.mlp(lp["mlp"], cfg,
                            (L.nonparam_layer_norm(hh) if cfg.nonparam_ln
                             else L.rms_norm(hh, lp["n2"])))
            return _c(hh), kv

        h, kvs = jax.lax.scan(body, h, params["layers"])
        h = (L.rms_norm(h, params["final_norm"]) if not cfg.nonparam_ln
             else L.nonparam_layer_norm(h))
        logits = _head(params, cfg, h[:, -1:])[:, 0]
        cache = {"k": jnp.moveaxis(kvs[0], 0, 0), "v": kvs[1]}  # (L,B,S,Hkv,hd)
        return logits, {"k": kvs[0], "v": kvs[1], "len": S}

    def init_cache(batch, max_len):
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                                cfg.hd), jnp.bfloat16),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                                cfg.hd), jnp.bfloat16)}

    def decode_step(params, cache, token, cur_len):
        h = params["embed"][token][:, None, :].astype(jnp.bfloat16)

        def body(hh, ins):
            lp, kc, vc = ins
            hh, nc = _dense_block_decode(lp, cfg, hh, {"k": kc, "v": vc},
                                         cur_len)
            return _c(hh), (nc["k"], nc["v"])

        h, (nk, nv) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                             cache["v"]))
        h = (L.rms_norm(h, params["final_norm"]) if not cfg.nonparam_ln
             else L.nonparam_layer_norm(h))
        logits = _head(params, cfg, h)[:, 0]
        return logits, {"k": nk, "v": nv}

    return ModelApi(cfg, init, loss, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# family: deepseek MoE (MLA + experts + optional MTP)
# ---------------------------------------------------------------------------


def build_moe(cfg: ArchConfig, mesh=None, dp_axes=("data",),
              remat: str = "block") -> ModelApi:
    nd = cfg.moe.first_dense
    nm = cfg.n_layers - nd
    _c = make_constrainer(mesh, dp_axes)

    def init(key):
        ks = jax.random.split(key, 6)
        dense_layers = jax.vmap(
            lambda k: _init_mla_block(k, cfg, use_moe=False))(
            jax.random.split(ks[0], nd))
        moe_layers = jax.vmap(
            lambda k: _init_mla_block(k, cfg, use_moe=True))(
            jax.random.split(ks[1], nm))
        p = {"embed": _embed_init(ks[2], cfg),
             "dense_layers": dense_layers, "moe_layers": moe_layers,
             "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
             "lm_head": L.dense_init(ks[3], cfg.d_model, cfg.vocab_padded)}
        if cfg.mtp:
            k1, k2 = jax.random.split(ks[4])
            p["mtp"] = {"proj": L.dense_init(k1, 2 * cfg.d_model, cfg.d_model),
                        "block": _init_mla_block(k2, cfg, use_moe=False),
                        "norm": jnp.ones((cfg.d_model,), jnp.float32)}
        return p

    def backbone(params, h, collect_kv=False):
        aux_total = 0.0
        kvs = []

        def mk_body():
            def body(carry, lp):
                hh, aux = carry
                hh, a, kv = _mla_block(lp, cfg, hh, mesh=mesh, dp_axes=dp_axes)
                return (_c(hh), aux + a), kv if collect_kv else None
            return jax.checkpoint(body, prevent_cse=False) if remat != "none" else body

        (h, aux_total), kv_d = jax.lax.scan(mk_body(), (h, 0.0),
                                            params["dense_layers"])
        (h, aux_total), kv_m = jax.lax.scan(mk_body(), (h, aux_total),
                                            params["moe_layers"])
        return h, aux_total, (kv_d, kv_m)

    def loss(params, batch):
        tok = batch["tokens"]
        h = _c(params["embed"][tok].astype(jnp.bfloat16))
        h, aux, _ = backbone(params, h)
        hn = L.rms_norm(h, params["final_norm"])
        tgt = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tok[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        ce = chunked_ce(params, cfg, hn, tgt, mask)
        metrics = {"ce": ce, "aux": aux}
        total = ce + aux
        if cfg.mtp:
            # MTP: predict t+2 from [h_t ; emb_{t+1}]
            emb_next = jnp.pad(params["embed"][tok][:, 1:], ((0, 0), (0, 1), (0, 0)))
            hm = jnp.concatenate([h.astype(jnp.float32), emb_next], -1)
            hm = (hm.astype(CDTYPE) @ params["mtp"]["proj"].astype(CDTYPE))
            hm, _, _ = _mla_block(params["mtp"]["block"], cfg,
                                  hm.astype(jnp.bfloat16), mesh=mesh,
                                  dp_axes=dp_axes)
            hm = L.rms_norm(hm, params["mtp"]["norm"])
            tgt2 = jnp.pad(tok[:, 2:], ((0, 0), (0, 2)))
            mask2 = jnp.pad(jnp.ones_like(tok[:, 2:], jnp.float32),
                            ((0, 0), (0, 2)))
            mtp_ce = chunked_ce(params, cfg, hm, tgt2, mask2)
            metrics["mtp_ce"] = mtp_ce
            total = total + 0.3 * mtp_ce
        return total, metrics

    def prefill(params, batch):
        tok = batch["tokens"]
        h = params["embed"][tok].astype(jnp.bfloat16)
        h, _, (kv_d, kv_m) = backbone(params, h, collect_kv=True)
        hn = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, hn[:, -1:])[:, 0]
        cache = {"dense": {"c_kv": kv_d[0], "k_rope": kv_d[1]},
                 "moe": {"c_kv": kv_m[0], "k_rope": kv_m[1]}}
        return logits, cache

    def init_cache(batch, max_len):
        m = cfg.mla
        def mk(n):
            return {"c_kv": jnp.zeros((n, batch, max_len, m.kv_lora), jnp.bfloat16),
                    "k_rope": jnp.zeros((n, batch, max_len, m.rope_dim), jnp.bfloat16)}
        return {"dense": mk(nd), "moe": mk(nm)}

    def decode_step(params, cache, token, cur_len):
        h = params["embed"][token][:, None, :].astype(jnp.bfloat16)

        def body(hh, ins):
            lp, ck, kr = ins
            hh, nc = _mla_block_decode(lp, cfg, hh, {"c_kv": ck, "k_rope": kr},
                                       cur_len, mesh=mesh, dp_axes=dp_axes)
            return _c(hh), (nc["c_kv"], nc["k_rope"])

        h, (ck_d, kr_d) = jax.lax.scan(body, h, (params["dense_layers"],
                                                 cache["dense"]["c_kv"],
                                                 cache["dense"]["k_rope"]))
        h, (ck_m, kr_m) = jax.lax.scan(body, h, (params["moe_layers"],
                                                 cache["moe"]["c_kv"],
                                                 cache["moe"]["k_rope"]))
        hn = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, hn)[:, 0]
        return logits, {"dense": {"c_kv": ck_d, "k_rope": kr_d},
                        "moe": {"c_kv": ck_m, "k_rope": kr_m}}

    return ModelApi(cfg, init, loss, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# family: ssm (mamba2)
# ---------------------------------------------------------------------------


def build_ssm(cfg: ArchConfig, mesh=None, dp_axes=("data",),
              remat: str = "block") -> ModelApi:
    _c = make_constrainer(mesh, dp_axes)

    def init(key):
        ks = jax.random.split(key, cfg.n_layers + 2)
        layers = jax.vmap(lambda k: _init_mamba_block(k, cfg))(
            jnp.stack(ks[: cfg.n_layers]))
        return {"embed": _embed_init(ks[-1], cfg), "layers": layers,
                "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}

    def loss(params, batch):
        tok = batch["tokens"]
        h = _c(params["embed"][tok].astype(jnp.bfloat16))
        body = lambda hh, lp: (_c(_mamba_block(lp, cfg, hh)), None)
        if remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, h, params["layers"])
        h = L.rms_norm(h, params["final_norm"])
        tgt = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tok[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        ce = chunked_ce(params, cfg, h, tgt, mask)
        return ce, {"ce": ce}

    def prefill(params, batch):
        tok = batch["tokens"]
        h = params["embed"][tok].astype(jnp.bfloat16)

        def body(hh, lp):
            hh, st = _mamba_block(lp, cfg, hh, return_state=True)
            return hh, st

        h, states = jax.lax.scan(body, h, params["layers"])
        h = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, h[:, -1:])[:, 0]
        return logits, states

    def init_cache(batch, max_len):
        h0, c0 = M.init_mamba_state(cfg, batch, jnp.bfloat16)
        return (jnp.broadcast_to(h0, (cfg.n_layers,) + h0.shape),
                jnp.broadcast_to(c0, (cfg.n_layers,) + c0.shape))

    def decode_step(params, cache, token, cur_len):
        h = params["embed"][token][:, None, :].astype(jnp.bfloat16)

        def body(hh, ins):
            lp, st_h, st_c = ins
            hh, st = _mamba_block_decode(lp, cfg, hh, (st_h, st_c))
            return hh, st

        h, states = jax.lax.scan(body, h, (params["layers"],) + tuple(cache))
        h = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, h)[:, 0]
        return logits, states

    return ModelApi(cfg, init, loss, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# family: hybrid (jamba)
# ---------------------------------------------------------------------------


def build_hybrid(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                 remat: str = "block") -> ModelApi:
    G = cfg.n_layers // cfg.attn_every         # groups
    per = cfg.attn_every                        # layers per group
    off = cfg.attn_offset
    n_mamba = per - 1
    moe_pos = [i for i in range(per) if i % 2 == 1] if cfg.moe.every_other \
        else list(range(per))
    mlp_pos = [i for i in range(per) if i not in moe_pos]
    _c = make_constrainer(mesh, dp_axes)

    def init_group(key):
        ks = jax.random.split(key, 4)
        return {
            "mamba": jax.vmap(lambda k: _init_mamba_block(k, cfg))(
                jax.random.split(ks[0], n_mamba)),
            "attn": {"attn": A.init_attention(ks[1], cfg),
                     "n1": jnp.ones((cfg.d_model,), jnp.float32)},
            "moe": jax.vmap(lambda k: MOE.init_moe(k, cfg))(
                jax.random.split(ks[2], len(moe_pos))),
            "mlp": jax.vmap(lambda k: L.init_mlp(k, cfg))(
                jax.random.split(ks[3], len(mlp_pos))),
            "ffn_norms": jnp.ones((per, cfg.d_model), jnp.float32),
        }

    def init(key):
        ks = jax.random.split(key, G + 3)
        groups = jax.vmap(init_group)(jnp.stack(ks[:G]))
        p = {"embed": _embed_init(ks[-1], cfg), "groups": groups,
             "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_init(ks[-2], cfg.d_model, cfg.vocab_padded)
        return p

    def group_fwd(gp, h, *, collect=False):
        aux = 0.0
        mi = ei = oi = 0
        kv = None
        states = []
        for i in range(per):
            if i == off:
                a = A.attention_forward(
                    gp["attn"]["attn"], cfg,
                    L.rms_norm(h, gp["attn"]["n1"]), kind="causal",
                    return_kv=collect)
                if collect:
                    a, kv = a
                h = h + a
            else:
                lp = jax.tree.map(lambda x: x[mi], gp["mamba"])
                if collect:
                    h, st = _mamba_block(lp, cfg, h, return_state=True)
                    states.append(st)
                else:
                    h = _mamba_block(lp, cfg, h)
                mi += 1
            hn = L.rms_norm(h, gp["ffn_norms"][i])
            if i in moe_pos:
                mp = jax.tree.map(lambda x: x[oi], gp["moe"])
                f, a2 = MOE.moe_forward(mp, cfg, hn, mesh=mesh, dp_axes=dp_axes)
                aux = aux + a2
                oi += 1
            else:
                mp = jax.tree.map(lambda x: x[ei], gp["mlp"])
                f = L.mlp(mp, cfg, hn)
                ei += 1
            h = _c(h + f)
        if collect:
            st_h = jnp.stack([s[0] for s in states])
            st_c = jnp.stack([s[1] for s in states])
            return h, aux, (kv, (st_h, st_c))
        return h, aux

    def loss(params, batch):
        tok = batch["tokens"]
        h = _c(params["embed"][tok].astype(jnp.bfloat16))
        body = lambda c, gp: ((lambda r: (r[0], c[1] + r[1]))(group_fwd(gp, c[0])), None)
        if remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        (h, aux), _ = jax.lax.scan(body, (h, 0.0), params["groups"])
        h = L.rms_norm(h, params["final_norm"])
        tgt = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tok[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        ce = chunked_ce(params, cfg, h, tgt, mask)
        return ce + aux, {"ce": ce, "aux": aux}

    def prefill(params, batch):
        tok = batch["tokens"]
        h = params["embed"][tok].astype(jnp.bfloat16)

        def body(hh, gp):
            hh, _, (kv, st) = group_fwd(gp, hh, collect=True)
            return hh, (kv, st)

        h, (kvs, sts) = jax.lax.scan(body, h, params["groups"])
        h = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, h[:, -1:])[:, 0]
        return logits, {"kv": {"k": kvs[0], "v": kvs[1]}, "ssm": sts}

    def init_cache(batch, max_len):
        h0, c0 = M.init_mamba_state(cfg, batch, jnp.bfloat16)
        return {"kv": {"k": jnp.zeros((G, batch, max_len, cfg.n_kv_heads,
                                       cfg.hd), jnp.bfloat16),
                       "v": jnp.zeros((G, batch, max_len, cfg.n_kv_heads,
                                       cfg.hd), jnp.bfloat16)},
                "ssm": (jnp.broadcast_to(h0, (G, n_mamba) + h0.shape),
                        jnp.broadcast_to(c0, (G, n_mamba) + c0.shape))}

    def group_decode(gp, h, kv, st, cur_len):
        mi = ei = oi = 0
        new_st_h, new_st_c = [], []
        new_kv = kv
        for i in range(per):
            if i == off:
                a, new_kv = A.attention_decode(
                    gp["attn"]["attn"], cfg, L.rms_norm(h, gp["attn"]["n1"]),
                    kv, cur_len)
                h = h + a
            else:
                lp = jax.tree.map(lambda x: x[mi], gp["mamba"])
                s = (st[0][mi], st[1][mi])
                h, ns = _mamba_block_decode(lp, cfg, h, s)
                new_st_h.append(ns[0])
                new_st_c.append(ns[1])
                mi += 1
            hn = L.rms_norm(h, gp["ffn_norms"][i])
            if i in moe_pos:
                mp = jax.tree.map(lambda x: x[oi], gp["moe"])
                f, _ = MOE.moe_forward(mp, cfg, hn, mesh=mesh, dp_axes=dp_axes)
                oi += 1
            else:
                mp = jax.tree.map(lambda x: x[ei], gp["mlp"])
                f = L.mlp(mp, cfg, hn)
                ei += 1
            h = h + f
        return h, new_kv, (jnp.stack(new_st_h), jnp.stack(new_st_c))

    def decode_step(params, cache, token, cur_len):
        h = params["embed"][token][:, None, :].astype(jnp.bfloat16)

        def body(hh, ins):
            gp, kc, vc, sh, sc = ins
            hh, nkv, nst = group_decode(gp, hh, {"k": kc, "v": vc},
                                        (sh, sc), cur_len)
            return hh, (nkv["k"], nkv["v"], nst[0], nst[1])

        h, (nk, nv, nsh, nsc) = jax.lax.scan(
            body, h, (params["groups"], cache["kv"]["k"], cache["kv"]["v"],
                      cache["ssm"][0], cache["ssm"][1]))
        h = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, h)[:, 0]
        return logits, {"kv": {"k": nk, "v": nv}, "ssm": (nsh, nsc)}

    return ModelApi(cfg, init, loss, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# family: encdec (seamless)
# ---------------------------------------------------------------------------


def build_encdec(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                 remat: str = "block") -> ModelApi:
    _c = make_constrainer(mesh, dp_axes)

    def _init_enc_block(key):
        return _init_dense_block(key, cfg)

    def _init_dec_block(key):
        init_n, _ = _norm_fns(cfg)
        ks = jax.random.split(key, 6)
        return {"attn": A.init_attention(ks[0], cfg),
                "xattn": A.init_attention(ks[1], cfg),
                "mlp": L.init_mlp(ks[2], cfg),
                "n1": init_n(ks[3], cfg.d_model),
                "nx": init_n(ks[4], cfg.d_model),
                "n2": init_n(ks[5], cfg.d_model)}

    def init(key):
        ks = jax.random.split(key, 5)
        enc = jax.vmap(_init_enc_block)(jax.random.split(ks[0], cfg.enc_layers))
        dec = jax.vmap(_init_dec_block)(jax.random.split(ks[1], cfg.n_layers))
        return {"embed": _embed_init(ks[2], cfg), "enc": enc, "dec": dec,
                "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
                "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
                "lm_head": L.dense_init(ks[3], cfg.d_model, cfg.vocab_padded)}

    def encode(params, src):
        h = src.astype(jnp.bfloat16)
        body = lambda hh, lp: (_c(_dense_block(lp, cfg, hh, kind="full")), None)
        if remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, h, params["enc"])
        return L.rms_norm(h, params["enc_norm"])

    def dec_block(lp, h, mem, collect=False):
        h = h + A.attention_forward(lp["attn"], cfg, L.rms_norm(h, lp["n1"]),
                                    kind="causal")
        x = A.attention_forward(lp["xattn"], cfg, L.rms_norm(h, lp["nx"]),
                                memory=mem, return_kv=collect)
        if collect:
            x, ckv = x
        h = h + x
        h = h + L.mlp(lp["mlp"], cfg, L.rms_norm(h, lp["n2"]))
        return (h, ckv) if collect else h

    def loss(params, batch):
        mem = encode(params, batch["src_embeds"])
        tok = batch["tokens"]
        h = params["embed"][tok].astype(jnp.bfloat16)
        body = lambda hh, lp: (_c(dec_block(lp, hh, mem)), None)
        if remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, h, params["dec"])
        h = L.rms_norm(h, params["final_norm"])
        tgt = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tok[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        ce = chunked_ce(params, cfg, h, tgt, mask)
        return ce, {"ce": ce}

    def prefill(params, batch):
        """Encode source + run decoder over the prompt tokens, caching both
        self-attn KV and cross-attn KV (computed once from memory)."""
        mem = encode(params, batch["src_embeds"])
        tok = batch["tokens"]
        h = params["embed"][tok].astype(jnp.bfloat16)

        def body(hh, lp):
            hh2 = hh + A.attention_forward(lp["attn"], cfg,
                                           L.rms_norm(hh, lp["n1"]),
                                           kind="causal")
            # self kv for cache
            _, skv = A.attention_forward(lp["attn"], cfg,
                                         L.rms_norm(hh, lp["n1"]),
                                         kind="causal", return_kv=True)
            x, ckv = A.attention_forward(lp["xattn"], cfg,
                                         L.rms_norm(hh2, lp["nx"]),
                                         memory=mem, return_kv=True)
            hh2 = hh2 + x
            hh2 = hh2 + L.mlp(lp["mlp"], cfg, L.rms_norm(hh2, lp["n2"]))
            return hh2, (skv, ckv)

        h, (skv, ckv) = jax.lax.scan(body, h, params["dec"])
        h = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, h[:, -1:])[:, 0]
        return logits, {"self": {"k": skv[0], "v": skv[1]},
                        "cross": {"k": ckv[0], "v": ckv[1]}}

    def init_cache(batch, max_len, enc_len=1024):
        zs = lambda s: jnp.zeros(s, jnp.bfloat16)
        return {"self": {"k": zs((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)),
                         "v": zs((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd))},
                "cross": {"k": zs((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.hd)),
                          "v": zs((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.hd))}}

    def decode_step(params, cache, token, cur_len):
        h = params["embed"][token][:, None, :].astype(jnp.bfloat16)

        def body(hh, ins):
            lp, sk, sv, ck, cv = ins
            a, nself = A.attention_decode(lp["attn"], cfg,
                                          L.rms_norm(hh, lp["n1"]),
                                          {"k": sk, "v": sv}, cur_len)
            hh = hh + a
            x, _ = A.attention_decode(lp["xattn"], cfg,
                                      L.rms_norm(hh, lp["nx"]),
                                      {"k": ck, "v": cv}, cur_len, cross=True)
            hh = hh + x
            hh = hh + L.mlp(lp["mlp"], cfg, L.rms_norm(hh, lp["n2"]))
            return hh, (nself["k"], nself["v"])

        h, (nk, nv) = jax.lax.scan(body, h, (params["dec"],
                                             cache["self"]["k"], cache["self"]["v"],
                                             cache["cross"]["k"], cache["cross"]["v"]))
        h = L.rms_norm(h, params["final_norm"])
        logits = _head(params, cfg, h)[:, 0]
        return logits, {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}

    return ModelApi(cfg, init, loss, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------


def build_model(cfg: ArchConfig, mesh=None, dp_axes=("data",),
                remat: str = "block") -> ModelApi:
    fam = {"dense": build_dense, "vlm": build_dense, "moe": build_moe,
           "ssm": build_ssm, "hybrid": build_hybrid, "encdec": build_encdec}
    return fam[cfg.family](cfg, mesh=mesh, dp_axes=dp_axes, remat=remat)
