"""GQA/MQA attention module (projections + RoPE + qk_norm + cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (CDTYPE, apply_rope, blockwise_attention,
                                 decode_attention, dense_init, rms_norm)


def init_attention(key, cfg, *, cross: bool = False):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * hd),
        "wk": dense_init(ks[1], d, Hkv * hd),
        "wv": dense_init(ks[2], d, Hkv * hd),
        "wo": dense_init(ks[3], H * hd, d),
    }
    if cfg.qk_norm:
        p["q_gamma"] = jnp.ones((hd,), jnp.float32)
        p["k_gamma"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(params, cfg, xq, xkv, q_positions, *, rope: bool):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xq_c, xkv_c = xq.astype(CDTYPE), xkv.astype(CDTYPE)
    q = (xq_c @ params["wq"].astype(CDTYPE)).reshape(B, Sq, H, hd)
    k = (xkv_c @ params["wk"].astype(CDTYPE)).reshape(B, Skv, Hkv, hd)
    v = (xkv_c @ params["wv"].astype(CDTYPE)).reshape(B, Skv, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_gamma"])
        k = rms_norm(k, params["k_gamma"])
    if rope:
        kv_positions = jnp.arange(Skv)[None, :] if Sq != Skv else q_positions
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def attention_forward(params, cfg, x, *, kind="causal", prefix_len=0,
                      memory=None, return_kv=False):
    """Training / prefill path.  ``memory`` (B, Sm, D) switches to
    cross-attention (no RoPE, full mask)."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :]
    if memory is None:
        q, k, v = _qkv(params, cfg, x, x, pos, rope=True)
    else:
        q, k, v = _qkv(params, cfg, x, memory, pos, rope=False)
        kind = "full"
    out = blockwise_attention(q, k, v, kind=kind, prefix_len=prefix_len,
                              block_q=cfg.attn_block_q,
                              block_kv=cfg.attn_block_kv)
    out = (out.reshape(B, S, -1).astype(CDTYPE) @ params["wo"].astype(CDTYPE)
           ).astype(x.dtype)
    return (out, (k, v)) if return_kv else out


def attention_decode(params, cfg, x, cache, cur_len, *, cross=False):
    """One-token decode.  ``cache`` = {'k','v'} (B, Smax, Hkv, hd) for self-
    attention (updated at cur_len-1) or static cross K/V (read-only)."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xc = x.astype(CDTYPE)
    q = (xc @ params["wq"].astype(CDTYPE)).reshape(B, 1, H, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_gamma"])
    if cross:
        k_cache, v_cache = cache["k"], cache["v"]
        if not cfg.qk_norm:
            pass
        out = decode_attention(q, k_cache, v_cache, k_cache.shape[1])
        new_cache = cache
    else:
        pos = jnp.broadcast_to(jnp.asarray(cur_len - 1), (B,))[:, None]
        k = (xc @ params["wk"].astype(CDTYPE)).reshape(B, 1, Hkv, hd)
        v = (xc @ params["wv"].astype(CDTYPE)).reshape(B, 1, Hkv, hd)
        if cfg.qk_norm:
            k = rms_norm(k, params["k_gamma"])
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        # scatter at per-batch positions (cur_len may be scalar or (B,))
        idx = jnp.broadcast_to(jnp.asarray(cur_len), (B,)) - 1
        rows = jnp.arange(B)
        k_cache = cache["k"].at[rows, idx].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[rows, idx].set(v[:, 0].astype(cache["v"].dtype))
        out = decode_attention(q, k_cache, v_cache, cur_len)
        new_cache = {"k": k_cache, "v": v_cache}
    out = (out.reshape(B, 1, -1).astype(CDTYPE) @ params["wo"].astype(CDTYPE)
           ).astype(x.dtype)
    return out, new_cache


def init_kv_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
