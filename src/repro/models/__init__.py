from repro.models.lm import build_model  # noqa: F401
