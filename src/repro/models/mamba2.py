"""Mamba-2 SSD (state-space duality) block.

Chunked SSD algorithm (Dao & Gu, arXiv:2405.21060 §6): the sequence is split
into chunks of Q tokens; within a chunk the output is a masked quadratic
(attention-like) term, across chunks a low-rank recurrence on the (H, P, N)
state is scanned.  ``ssd_naive`` is the O(S) sequential oracle used by the
property tests; decode is a single state update (the reason mamba2 runs the
long_500k shape: per-step cost is independent of context length).

Shapes: x (B, S, H, P) heads; A (H,) decay; B/C (B, S, N) (single group);
dt (B, S, H) softplus-positive step sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import CDTYPE, dense_init, rms_norm


def init_mamba(key, cfg):
    sc = cfg.ssm
    d = cfg.d_model
    d_inner = sc.expand * d
    H = d_inner // sc.head_dim
    ks = jax.random.split(key, 5)
    # in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
    d_in_proj = 2 * d_inner + 2 * sc.d_state + H
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj),
        "conv_w": jax.random.normal(ks[1], (sc.d_conv, d_inner + 2 * sc.d_state),
                                    jnp.float32) * 0.2,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, d),
    }


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv over (B, S, C); optional carried state
    (B, d_conv-1, C) for decode.  Returns (out, new_state)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], 1)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i][None, None]
              for i in range(k))
    return jax.nn.silu(out), full[:, -(k - 1):]


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, init_state=None):
    """Chunked SSD scan.  x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert S % Q == 0, (S, Q)
    xd = (x * dt[..., None]).reshape(Bsz, nc, Q, H, Pd)      # dt-weighted input
    dA = (dt * (-jnp.exp(A))[None, None, :]).reshape(Bsz, nc, Q, H)  # (B,nc,Q,H) <=0
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    seg = jnp.cumsum(dA, axis=2)                             # within-chunk cumsum
    total = seg[:, :, -1, :]                                 # (B,nc,H)

    # ---- intra-chunk (quadratic) term ------------------------------------
    # decay(q, k) = exp(seg_q - seg_k) for q >= k
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # clamp BEFORE exp: masked (q<k) entries have rel>0 and would overflow,
    # poisoning the backward with 0*inf = NaN
    rel = jnp.where(causal[None, None, :, :, None], rel, -1e9)
    gamma = jnp.exp(rel)
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc,
                    preferred_element_type=jnp.float32)      # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, gamma, xd,
                         preferred_element_type=jnp.float32)

    # ---- chunk states + inter-chunk recurrence ---------------------------
    # state_c = sum_k exp(total - seg_k) * B_k x_k   (contribution of chunk c)
    w = jnp.exp(total[:, :, None, :] - seg)                  # (B,nc,Q,H)
    st = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, w, xd,
                    preferred_element_type=jnp.float32)      # (B,nc,H,P,N)

    def scan_fn(h, inputs):
        st_c, tot_c = inputs                                 # (B,H,P,N), (B,H)
        h_out = h                                            # state BEFORE chunk c
        h_new = h * jnp.exp(tot_c)[:, :, None, None] + st_c
        return h_new, h_out

    h0 = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    h_fin, h_prev = jax.lax.scan(scan_fn, h0,
                                 (jnp.moveaxis(st, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # (B,nc,H,P,N)

    # ---- inter-chunk term: y += C_q exp(seg_q) h_prev ---------------------
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(seg), h_prev,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y.astype(x.dtype), h_fin


def ssd_naive(x, dt, A, Bm, Cm, *, init_state=None):
    """Sequential O(S) oracle: h_t = h_{t-1} e^{dt_t A} + dt_t B_t x_t."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    h0 = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * (-jnp.exp(A)))[:, :, None, None]   # (B,H,1,1)
        h = h * decay + (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def mamba_forward(params, cfg, u, *, init_state=None, conv_state=None,
                  return_state=False):
    """Full-sequence forward.  u (B, S, D)."""
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    B_, S, _ = u.shape
    proj = u.astype(CDTYPE) @ params["in_proj"].astype(CDTYPE)
    # split: z (d_inner) | xBC (d_inner + 2N) | dt (H)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner: 2 * d_inner + 2 * sc.d_state]
    dt_raw = proj[..., 2 * d_inner + 2 * sc.d_state:]
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], conv_state)
    xs = xBC[..., :d_inner].reshape(B_, S, H, sc.head_dim)
    Bm = xBC[..., d_inner: d_inner + sc.d_state].astype(jnp.float32)
    Cm = xBC[..., d_inner + sc.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    y, h = ssd_chunked(xs.astype(jnp.float32), dt, params["A_log"], Bm, Cm,
                       chunk=sc.chunk, init_state=init_state)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y, params["norm"]) * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(CDTYPE) @ params["out_proj"].astype(CDTYPE)).astype(u.dtype)
    if return_state:
        return out, (h, new_conv)
    return out


def mamba_decode(params, cfg, u, state):
    """One-token decode.  u (B, 1, D); state = (h (B,H,P,N), conv (B,k-1,C))."""
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    h, conv_state = state
    B_ = u.shape[0]
    proj = u.astype(CDTYPE) @ params["in_proj"].astype(CDTYPE)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner: 2 * d_inner + 2 * sc.d_state]
    dt_raw = proj[..., 2 * d_inner + 2 * sc.d_state:]
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], conv_state)
    xs = xBC[..., :d_inner].reshape(B_, H, sc.head_dim)
    Bm = xBC[:, 0, d_inner: d_inner + sc.d_state].astype(jnp.float32)
    Cm = xBC[:, 0, d_inner + sc.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    decay = jnp.exp(dt * (-jnp.exp(params["A_log"])))[:, :, None, None]
    h = h * decay + (dt[..., None] * xs.astype(jnp.float32))[..., None] \
        * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm) \
        + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, 1, d_inner)
    y = rms_norm(y, params["norm"]) * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(CDTYPE) @ params["out_proj"].astype(CDTYPE)).astype(u.dtype)
    return out, (h, new_conv)


def init_mamba_state(cfg, batch, dtype=jnp.float32):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    return (jnp.zeros((batch, H, sc.head_dim, sc.d_state), jnp.float32),
            jnp.zeros((batch, sc.d_conv - 1, d_inner + 2 * sc.d_state), dtype))
