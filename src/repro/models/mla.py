"""Multi-head Latent Attention (DeepSeek v2/v3).

Train/prefill run the standard "expanded" form; decode runs the ABSORBED
form: the rank-512 latent c_kv (+ shared rope key) is the entire KV cache,
W_uk is folded into the query and W_uv into the output projection, so
per-step attention reads S x (kv_lora + rope_dim) bytes instead of
S x 2 x H x hd — the production MLA trick, and the reason the DCO-attention
screening (DESIGN.md §4) composes so well here: stage-1 screening runs on the
same 512-dim latents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import CDTYPE, apply_rope, blockwise_attention, dense_init, rms_norm


def init_mla(key, cfg):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora),
        "q_norm": jnp.ones((m.q_lora,), jnp.float32),
        "wq_b": dense_init(ks[1], m.q_lora, H * (m.nope_dim + m.rope_dim)),
        "wkv_a": dense_init(ks[2], d, m.kv_lora + m.rope_dim),
        "kv_norm": jnp.ones((m.kv_lora,), jnp.float32),
        "wk_b": dense_init(ks[3], m.kv_lora, H * m.nope_dim),
        "wv_b": dense_init(ks[4], m.kv_lora, H * m.v_dim),
        "wo": dense_init(ks[5], H * m.v_dim, d),
    }


def _project_q(params, cfg, x, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    xc = x.astype(CDTYPE)
    ql = rms_norm(xc @ params["wq_a"].astype(CDTYPE), params["q_norm"])
    q = (ql.astype(CDTYPE) @ params["wq_b"].astype(CDTYPE)
         ).reshape(B, S, H, m.nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, cfg, x, positions):
    m = cfg.mla
    xc = x.astype(CDTYPE)
    kv = xc @ params["wkv_a"].astype(CDTYPE)           # (B, S, kv_lora+rope)
    c_kv = rms_norm(kv[..., : m.kv_lora], params["kv_norm"])
    k_rope = apply_rope(kv[..., None, m.kv_lora:], positions, cfg.rope_theta)
    return c_kv, k_rope[..., 0, :]                     # (B,S,kv_lora), (B,S,rope)


def mla_forward(params, cfg, x):
    """Expanded train/prefill attention; returns (out, (c_kv, k_rope))."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :]
    q_nope, q_rope = _project_q(params, cfg, x, pos)
    c_kv, k_rope = _project_kv_latent(params, cfg, x, pos)
    k_nope = (c_kv.astype(CDTYPE) @ params["wk_b"].astype(CDTYPE)
              ).reshape(B, S, H, m.nope_dim)
    v = (c_kv.astype(CDTYPE) @ params["wv_b"].astype(CDTYPE)
         ).reshape(B, S, H, m.v_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, S, H, m.rope_dim))], -1)
    # v_dim != qk head_dim: pad v for the shared blockwise kernel, trim after
    pad = (m.nope_dim + m.rope_dim) - m.v_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = blockwise_attention(q, k, v_p, kind="causal",
                              scale=1.0 / np.sqrt(m.nope_dim + m.rope_dim),
                              block_q=cfg.attn_block_q,
                              block_kv=cfg.attn_block_kv)
    out = out[..., : m.v_dim].reshape(B, S, H * m.v_dim)
    out = (out.astype(CDTYPE) @ params["wo"].astype(CDTYPE)).astype(x.dtype)
    return out, (c_kv, k_rope)


def mla_decode(params, cfg, x, cache, cur_len):
    """Absorbed one-token decode; cache = {'c_kv' (B,Smax,kv_lora),
    'k_rope' (B,Smax,rope)}."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(cur_len - 1), (B,))[:, None]
    q_nope, q_rope = _project_q(params, cfg, x, pos)         # (B,1,H,·)
    c_new, kr_new = _project_kv_latent(params, cfg, x, pos)  # (B,1,·)
    idx = jnp.broadcast_to(jnp.asarray(cur_len), (B,)) - 1
    rows = jnp.arange(B)
    c_kv = cache["c_kv"].at[rows, idx].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[rows, idx].set(
        kr_new[:, 0].astype(cache["k_rope"].dtype))
    # absorb W_uk into q:  q_eff[b,h,:] = q_nope[b,h] @ wk_b[h]^T
    wkb = params["wk_b"].astype(CDTYPE).reshape(m.kv_lora, H, m.nope_dim)
    q_eff = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], wkb,
                       preferred_element_type=jnp.float32)   # (B,H,kv_lora)
    s = (jnp.einsum("bhl,bsl->bhs", q_eff.astype(CDTYPE), c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(CDTYPE), k_rope,
                      preferred_element_type=jnp.float32))
    s = s / np.sqrt(m.nope_dim + m.rope_dim)
    valid = jnp.arange(c_kv.shape[1])[None, :] < jnp.broadcast_to(
        jnp.asarray(cur_len), (B,))[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", p.astype(CDTYPE), c_kv,
                     preferred_element_type=jnp.float32)     # (B,H,kv_lora)
    # absorb W_uv into the output projection
    wvb = params["wv_b"].astype(CDTYPE).reshape(m.kv_lora, H, m.v_dim)
    o = jnp.einsum("bhl,lhv->bhv", ctx.astype(CDTYPE), wvb,
                   preferred_element_type=jnp.float32)       # (B,H,v_dim)
    out = (o.reshape(B, 1, H * m.v_dim).astype(CDTYPE)
           @ params["wo"].astype(CDTYPE)).astype(x.dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def init_mla_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.rope_dim), dtype)}
