"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MLA kv_lora=512, MoE 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                    # dense-FFN layers (first_dense)
    vocab=102400, head_dim=192,    # nope 128 + rope 64
    act="swiglu",
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_dense=1),
)
