"""Architecture + run-shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # always-on shared experts
    first_dense: int = 0          # leading layers use dense FFN
    every_other: bool = False     # MoE on odd layers only (jamba)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    nonparam_ln: bool = False     # olmo: non-parametric LayerNorm
    rope_theta: float = 10_000.0
    act: str = "swiglu"           # swiglu | gelu | geglu
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0           # hybrid: 1 attn per this many layers
    attn_offset: int = 4          # hybrid: position of attn inside group
    enc_layers: int = 0           # encdec
    prefix_len: int = 0           # vlm/audio stub frontend tokens
    mtp: bool = False             # deepseek-v3 multi-token prediction head
    attn_block_q: int = 512       # blockwise-attention tile sizes (perf knob)
    attn_block_kv: int = 1024
    vocab_pad_mult: int = 256
    sub_quadratic: bool = False   # eligible for long_500k
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_mult
        return (self.vocab + m - 1) // m * m

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced copy for CPU smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k":    RunShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  RunShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   RunShape("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
