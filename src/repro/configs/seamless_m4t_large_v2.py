"""seamless-m4t-large-v2 [audio]: enc-dec, 24L each, d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206.  Modality frontend is a STUB: input_specs() provides
precomputed audio-frame embeddings (B, S_enc, d_model). [arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    act="gelu",
)
