"""Sharding rules: param/batch/cache PartitionSpecs with divisibility fallbacks.

Scheme (DESIGN.md §4):
  * dense 2D weights: P(fsdp, "model") — FSDP over the data axes on d_in,
    tensor parallel over "model" on d_out (row-parallel matrices transposed);
  * MoE expert stacks (E, D, F): experts over "model" (EP), d_model over the
    DP axes (FSDP) — matching models.moe's shard_map in_specs;
  * vocab over "model" for embed / lm_head;
  * batch over the DP axes; long-context (batch < dp) shards the KV-cache
    sequence axis over the DP axes instead (flash-decoding style).

Every rule passes through ``_maybe``: an axis is only used when the dim is
divisible by the mesh axis product, otherwise that dim replicates — this is
what absorbs starcoder2's 36 heads or mamba2's 3352-wide in_proj on a
16-way TP axis without special cases.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _axsize(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh, dim, axes):
    """axes if dim divides evenly, else None (replicate)."""
    return axes if (axes and dim % _axsize(mesh, axes) == 0) else None


# trailing-dims rules per leaf name: entries are "axes for that trailing dim"
# (None = replicate).  fsdp -> DP axes; tp -> "model".
_RULES = {
    # name: (trailing rank, per-dim axes) where 'F' = fsdp, 'T' = tp
    "embed":    ("T", "F"),
    "lm_head":  ("F", "T"),
    "wq": ("F", "T"), "wk": ("F", "T"), "wv": ("F", "T"), "wo": ("T", "F"),
    "wg": ("F", "T"), "wu": ("F", "T"), "wd": ("T", "F"),
    "w1": ("F", "T"), "w2": ("T", "F"),
    "wq_a": ("F", "T"), "wq_b": ("F", "T"),
    "wkv_a": ("F", "T"), "wk_b": ("F", "T"), "wv_b": ("F", "T"),
    "in_proj": ("F", "T"), "out_proj": ("T", "F"),
    "proj": ("F", "T"),
    "router": ("F", None),
    "conv_w": (None, None),
}

# MoE expert tensors (inside a params dict keyed 'moe' or hybrid group 'moe'):
# (E, D, F) / (E, F, D) — expert dim on TP, d_model dim on FSDP.
_MOE_RULES = {
    "wg": ("T", "F", None),
    "wu": ("T", "F", None),
    "wd": ("T", None, "F"),
    "router": ("F", None),     # (D, E): FSDP on d_model; gathered per layer
}


def _resolve(mesh, shape, rule, fsdp, tp):
    spec = [None] * len(shape)
    k = len(rule)
    for i, r in enumerate(rule):
        dim_idx = len(shape) - k + i
        if dim_idx < 0:
            continue
        axes = {"F": fsdp, "T": tp, None: None}[r]
        spec[dim_idx] = _maybe(mesh, shape[dim_idx], axes)
    return P(*spec)


def param_specs(params, mesh, *, fsdp=("data",), tp="model"):
    """Pytree of PartitionSpec matching ``params`` (works on shapes or
    arrays).  Leading stacked-layer dims are left replicated."""
    import jax

    def walk(tree, path):
        if tree is None:                    # e.g. non-parametric norms (olmo)
            return None
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, path + (str(i),)) for i, v in enumerate(tree))
        shape = tree.shape if hasattr(tree, "shape") else tuple(tree)
        name = path[-1] if path else ""
        in_moe = any(p in ("moe", "shared") for p in path[:-1])
        if in_moe and name in _MOE_RULES and path[-2] != "shared":
            return _resolve(mesh, shape, _MOE_RULES[name], fsdp, tp)
        rule = _RULES.get(name)
        if rule is None:
            return P()                      # norms / scalars: replicate
        return _resolve(mesh, shape, rule, fsdp, tp)

    return walk(params, ())


def batch_specs(batch, mesh, *, dp=("data",)):
    """tokens (B, S) etc: batch dim over DP if divisible."""
    def one(x):
        shape = x.shape if hasattr(x, "shape") else tuple(x)
        spec = [None] * len(shape)
        spec[0] = _maybe(mesh, shape[0], dp)
        return P(*spec)
    import jax
    return jax.tree.map(one, batch)


def cache_specs(cache, mesh, *, dp=("data",), tp="model", batch_axis=1,
                seq_axis=2):
    """KV caches (L, B, S, ...): batch over DP when divisible, otherwise the
    sequence axis over DP (long-context flash-decoding sharding).  SSM states
    (no seq axis at decode) replicate when batch is unshardable."""
    def one(x):
        shape = x.shape if hasattr(x, "shape") else tuple(x)
        spec = [None] * len(shape)
        if len(shape) > batch_axis and _maybe(mesh, shape[batch_axis], dp):
            spec[batch_axis] = dp
        elif len(shape) > seq_axis and _maybe(mesh, shape[seq_axis], dp):
            spec[seq_axis] = dp
        return P(*spec)
    import jax
    return jax.tree.map(one, cache)


def named(mesh, specs):
    import jax
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
