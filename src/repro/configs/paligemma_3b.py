"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384
vocab=257216.  SigLIP frontend is a STUB: input_specs() provides precomputed
patch embeddings as a 256-token prefix (prefix-LM mask). [arXiv:2407.07726; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, head_dim=256,
    act="geglu", prefix_len=256, tie_embeddings=True,
)
