"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave. [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    act="swiglu",
    attn_every=8, attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, every_other=True),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True,
)
