"""mamba2-130m [ssm]: 24L d_model=768 attn-free, vocab=50280, ssm_state=128,
SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True, tie_embeddings=True,
)
