"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MLA, MoE 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,                    # dense-FFN layers (first_dense)
    vocab=129280, head_dim=192,
    act="swiglu", mtp=True,
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128, v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  first_dense=3),
)
