"""Config registry: ``--arch <id>`` resolves through ARCHS."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig, MLAConfig, MoEConfig, RunShape, SSMConfig, SHAPES,
    applicable_shapes,
)

_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "olmo-1b": "olmo_1b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mamba2-130m": "mamba2_130m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "paligemma-3b": "paligemma_3b",
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (brief: small layers,
    few experts, tiny vocab)."""
    import dataclasses
    cfg = get_arch(name)
    kw = dict(n_layers=min(cfg.n_layers, 4), d_model=64, d_ff=128,
              vocab=512, head_dim=16, vocab_pad_mult=64)
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0
        if cfg.n_kv_heads == 1:
            kw["n_kv_heads"] = 1
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=32,
            first_dense=min(cfg.moe.first_dense, 1))
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora=32, q_lora=48, rope_dim=8, nope_dim=16, v_dim=16)
        kw["head_dim"] = 24
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.enc_layers:
        kw["enc_layers"] = min(cfg.enc_layers, 2)
    if cfg.attn_every:
        kw["n_layers"] = cfg.attn_every          # one hybrid group
    if cfg.prefix_len:
        kw["prefix_len"] = 8
    return cfg.scaled(**kw)
