"""The paper's own workload as a config: the distributed DCO retrieval engine.

This is the (arch, shape) cell "most representative of the paper's technique"
for the §Perf hillclimb: a production-scale vector corpus sharded over the
mesh, served with the two-stage DCO engine.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class RetrievalConfig:
    name: str = "dco-retrieval"
    dim: int = 768                  # wikipedia-like embeddings
    n_total: int = 100_000_000      # paper's max cardinality (Deep: 100M)
    d1: int = 128                   # stage-1 dims
    k: int = 100
    query_batch: int = 1024
    capacity: int = 4096            # stage-2 survivors per shard per query
    kind: str = "lb"                # PDScanning+ style certified lower bound


CONFIG = RetrievalConfig()
