from repro.search.ivf import IVFIndex  # noqa: F401
from repro.search.hnsw import HNSWIndex  # noqa: F401
