"""HNSW with pluggable DCO methods (paper §IV-C: HNSW on CPUs).

Host-side implementation (graph walks don't map to TPUs — DESIGN.md §3);
distance comparisons are routed through the method's staged screening in
*neighbor batches* (a node's adjacency list is screened as one block, which
is the batched analogue of per-edge DCOs and what a SIMD CPU build does too).

The DCO contract during search: a neighbor whose distance is proven > tau
(the current worst of the ef result set) is discarded WITHOUT an exact
distance — that is exactly where the paper's methods save time, and where
approximate methods may lose recall.

All entry points take a ``QueryBatch`` (prepped ctx + schedule + stats), so
there is no hidden schedule state on the index: build/insert/search each
carry their own batch and the graph object holds only the graph.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.engine import QueryBatch, ScanStats


class HNSWIndex:
    def __init__(self, m: int = 16, ef_construction: int = 100, *, seed: int = 0):
        self.m = m
        self.m0 = 2 * m
        self.efc = ef_construction
        self.rng = np.random.default_rng(seed)
        self.levels: list[int] = []
        self.links: list[list[np.ndarray]] = []   # node -> per-level neighbor ids
        self.entry = -1
        self.max_level = -1
        self.ml = 1.0 / np.log(m)

    # ------------------------------------------------------------------
    def _screen_batch(self, method, batch, qi, ids, tau_sq):
        """Staged screening + exact completion for a neighbor batch.
        Returns (surviving ids, exact squared distances)."""
        ids = np.asarray(ids, np.int64)
        D = method.state["D"]
        stats = batch.stats
        if stats is not None:
            stats.n_dco += len(ids)
            stats.dims_total += len(ids) * D
        alive = ids
        if np.isfinite(tau_sq):
            for d in method.stage_dims(batch.schedule):
                if len(alive) == 0:
                    break
                keep, charged = method.screen(alive, batch.ctx, qi, max(d, 1), tau_sq)
                if stats is not None:
                    stats.dims_scanned += len(alive) * charged
                alive = alive[keep]
        if len(alive) == 0:
            return alive, np.empty(0, np.float32)
        if stats is not None:
            stats.dims_scanned += len(alive) * D
        return alive, method.exact_sq(alive, batch.ctx, qi)

    def _search_layer(self, method, batch, qi, entry_ids, entry_ds, level, ef):
        """Classic ef-bounded best-first search on one layer."""
        visited = set(int(i) for i in entry_ids)
        cand = [(float(d), int(i)) for d, i in zip(entry_ds, entry_ids)]
        heapq.heapify(cand)
        result = [(-float(d), int(i)) for d, i in zip(entry_ds, entry_ids)]
        heapq.heapify(result)
        while cand:
            d, u = heapq.heappop(cand)
            if len(result) >= ef and d > -result[0][0]:
                break
            nbrs = [v for v in self.links[u][level] if v not in visited]
            if not nbrs:
                continue
            visited.update(int(v) for v in nbrs)
            tau = -result[0][0] if len(result) >= ef else np.inf
            alive, ex = self._screen_batch(method, batch, qi, nbrs, tau)
            for dv, v in zip(ex, alive):
                dv, v = float(dv), int(v)
                if len(result) < ef or dv < -result[0][0]:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(result, (-dv, v))
                    if len(result) > ef:
                        heapq.heappop(result)
        out = sorted(((-nd, i) for nd, i in result))
        return ([d for d, _ in out], [i for _, i in out])

    # ------------------------------------------------------------------
    def build(self, X: np.ndarray, *, method, schedule=None,
              stats: ScanStats | None = None) -> "HNSWIndex":
        """Incremental construction; ``method`` must already be fitted on X
        (or be fitted-and-appended in lockstep for the dynamic scenario)."""
        X = np.asarray(X, np.float32)
        sched = schedule if schedule is not None else []
        batch = QueryBatch.create(method, X, sched, stats)  # nodes double as queries
        for i in range(X.shape[0]):
            self._insert_one(method, batch, i)
        return self

    def insert_batch(self, method, Xnew: np.ndarray, stats=None, schedule=None):
        """Dynamic insertion (paper §V-E): append to method state, then link."""
        start = method.state["N"]
        method.append(Xnew)
        sched = schedule if schedule is not None else []
        batch = QueryBatch.create(method, Xnew, sched, stats)
        for j in range(Xnew.shape[0]):
            self._insert_one(method, batch, j, node_id=start + j)

    def _insert_one(self, method, batch, qi, node_id=None):
        node = len(self.levels) if node_id is None else node_id
        level = int(-np.log(max(self.rng.random(), 1e-12)) * self.ml)
        while len(self.levels) <= node:
            self.levels.append(0)
            self.links.append([])
        self.levels[node] = level
        self.links[node] = [np.empty(0, np.int64) for _ in range(level + 1)]
        if self.entry < 0:
            self.entry, self.max_level = node, level
            return
        eps = [self.entry]
        epd = [float(method.exact_sq(np.array([self.entry]), batch.ctx, qi)[0])]
        for lv in range(self.max_level, level, -1):
            epd, eps = self._search_layer(method, batch, qi, eps, epd, lv, 1)
        for lv in range(min(level, self.max_level), -1, -1):
            ds, ids = self._search_layer(method, batch, qi, eps, epd, lv, self.efc)
            mmax = self.m0 if lv == 0 else self.m
            nbrs = np.asarray(ids[: self.m], np.int64)
            self.links[node][lv] = nbrs
            for v in nbrs:                         # bidirectional + degree cap
                lk = self.links[v][lv]
                lk = np.append(lk, node)
                if len(lk) > mmax:
                    dd = method.exact_sq(lk, batch.ctx, qi)   # prune farthest from new node's view
                    lk = lk[np.argsort(dd)[:mmax]]
                self.links[v][lv] = lk
            eps, epd = ids, ds
        if level > self.max_level:
            self.entry, self.max_level = node, level

    # ------------------------------------------------------------------
    def search(self, method, batch: QueryBatch, qi: int, k: int, ef: int):
        eps = [self.entry]
        epd = [float(method.exact_sq(np.array([self.entry]), batch.ctx, qi)[0])]
        for lv in range(self.max_level, 0, -1):
            epd, eps = self._search_layer(method, batch, qi, eps, epd, lv, 1)
        ds, ids = self._search_layer(method, batch, qi, eps, epd, 0, max(ef, k))
        return np.asarray(ds[:k], np.float32), np.asarray(ids[:k], np.int64)
