"""IVF index with pluggable DCO methods (paper §IV-C: IVF on accelerators).

Build: batched-Lloyd k-means over the base vectors -> ``n_list`` partitions.
Search: rank partitions by centroid distance, take ``nprobe``, run the DCO
engine over their concatenated candidate lists.

Construction itself can be DCO-accelerated (paper §V-D): the assignment step
is a top-1 search over centroids, which we route through the same staged
screening when a method is supplied.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import QueryBatch, scan_topk


def _kmeans_assign(X, cent, *, method=None, schedule=None, stats=None, block=8192):
    """Nearest-centroid assignment; optionally DCO-screened (top-1 search)."""
    n = X.shape[0]
    out = np.empty(n, np.int64)
    if method is None:
        cn = (cent ** 2).sum(1)
        for lo in range(0, n, block):
            hi = min(lo + block, n)
            d2 = cn[None] - 2.0 * X[lo:hi] @ cent.T
            out[lo:hi] = d2.argmin(1)
        return out
    batch = QueryBatch.create(method, X, schedule, stats)  # base rows as queries
    ids = np.arange(cent.shape[0])
    for i in range(n):
        # small blocks so the running top-1 threshold starts pruning early
        _, bi = scan_topk(method, batch, i, ids, 1, block=32)
        out[i] = bi[0]
    return out


class IVFIndex:
    def __init__(self, n_list: int = 256, *, seed: int = 0, kmeans_iters: int = 10):
        self.n_list = n_list
        self.seed = seed
        self.kmeans_iters = kmeans_iters
        self.centroids: np.ndarray | None = None
        self.lists: list | None = None          # list of np.int64 arrays
        self.n = 0

    # -- construction --------------------------------------------------------
    def build(self, X: np.ndarray, *, method=None, schedule=None) -> "IVFIndex":
        """K-means + partition fill.  ``method`` accelerates the assignment
        DCOs during construction (Fig. 9 scenario); the final layout is
        identical for all methods (paper App. A: fixed data layout)."""
        X = np.asarray(X, np.float32)
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        k = min(self.n_list, max(1, n // 8))
        cent = X[rng.choice(n, k, replace=False)].copy()
        sub = X[rng.choice(n, min(n, 50_000), replace=False)]
        for _ in range(self.kmeans_iters):           # Lloyd on a training slice
            a = _kmeans_assign(sub, cent)
            sums = np.zeros((k, X.shape[1]), np.float64)
            np.add.at(sums, a, sub)
            cnt = np.bincount(a, minlength=k).astype(np.float64)
            upd = cnt > 0
            cent[upd] = (sums[upd] / cnt[upd, None]).astype(np.float32)
        # final assignment pass is where DCO acceleration bites (n x k DCOs)
        assign = _kmeans_assign(X, cent, method=method, schedule=schedule)
        self.centroids = cent
        self.lists = [np.where(assign == j)[0].astype(np.int64) for j in range(k)]
        self.n = n
        return self

    def insert(self, new_ids: np.ndarray, Xnew: np.ndarray,
               *, method=None, schedule=None) -> np.ndarray:
        """Dynamic inserts (paper §V-E): assign new vectors to partitions;
        DCO screening accelerates the assignment.  Returns the per-row
        partition assignment (the jax backend's delta segment needs it to
        probe delta rows without re-deriving the layout)."""
        a = _kmeans_assign(np.asarray(Xnew, np.float32), self.centroids,
                           method=method, schedule=schedule)
        for j, gid in zip(a, new_ids):
            self.lists[j] = np.append(self.lists[j], gid)
        self.n += len(new_ids)
        return a

    # -- search ---------------------------------------------------------------
    def probe_ids(self, q: np.ndarray, nprobe: int) -> np.ndarray:
        d2 = ((self.centroids - q) ** 2).sum(1)
        order = np.argsort(d2)[:nprobe]
        lists = [self.lists[j] for j in order]
        return np.concatenate(lists) if lists else np.empty(0, np.int64)

    def search(self, method, batch: QueryBatch, qi: int, k: int, nprobe: int,
               *, policy=None, deadline_ts=None):
        """Probe ``nprobe`` partitions and run the staged DCO scan over their
        concatenated candidates; ``policy`` threads the adaptive fdscan
        fallback (core.policy) into the scan and ``deadline_ts`` its anytime
        deadline (DESIGN.md §7; coverage is over probed candidates)."""
        cands = self.probe_ids(batch.Q[qi], nprobe)
        return scan_topk(method, batch, qi, cands, k, policy=policy,
                         deadline_ts=deadline_ts)
