"""``repro.serving`` — serving fronts.

``ServingEngine`` is the continuous-batching loop for LM decode;
``SearchService`` applies the same fixed-slot pattern to vector search
(batched single-query admission + the LSM-style delta write path,
DESIGN.md §6).  ``ReplicatedService`` stacks the fault-tolerant replica
tier on top — retry/backoff, hedged dispatch, breaker-gated routing, and
shard-loss graceful degradation (DESIGN.md §10).
"""
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.replica import (REPLICA_MODES,  # noqa: F401
                                   ReplicaDispatchError, ReplicaPolicy,
                                   ReplicatedService, open_replicated)
from repro.serving.search_service import (SearchRequest,  # noqa: F401
                                          SearchService)
