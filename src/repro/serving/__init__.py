"""``repro.serving`` — serving fronts.

``ServingEngine`` is the continuous-batching loop for LM decode;
``SearchService`` applies the same fixed-slot pattern to vector search
(batched single-query admission + the LSM-style delta write path,
DESIGN.md §6).
"""
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.search_service import (SearchRequest,  # noqa: F401
                                          SearchService)
