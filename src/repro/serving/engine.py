"""Batched serving engine: continuous batching over the compiled decode step.

The device-side steps are ``api.prefill`` / ``api.decode_step``; this host
loop packs requests into fixed decode slots (XLA-friendly static shapes),
admits new requests as slots free up, and tracks PER-SLOT sequence lengths —
decode_step accepts a vector ``cur_len`` so heterogeneous requests coexist in
one batch (the continuous-batching pattern, minus paged KV; contiguous
per-slot cache, page tables noted as an extension in DESIGN.md).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    out: list = field(default_factory=list)
    cursor: int = 0              # how many prompt tokens have been fed


class ServingEngine:
    def __init__(self, api, *, slots: int = 8, max_len: int = 512):
        self.api = api
        self.slots = slots
        self.max_len = max_len
        self.decode = jax.jit(api.decode_step)

    def run(self, params, requests: list, *, max_steps: int = 100_000):
        """Serve ``requests`` to completion; returns {rid: generated ids}.

        Prompts are fed token-at-a-time through the same decode path (one
        compiled program for the whole engine); slots with exhausted prompts
        sample greedily.  Idle slots replay position 1 harmlessly.
        """
        cfg = self.api.cfg
        queue = deque(requests)      # popleft admission is O(1), not O(n)
        cache = self.api.init_cache(self.slots, self.max_len)
        lens = np.zeros(self.slots, np.int64)          # tokens already in cache
        cur_tok = np.zeros(self.slots, np.int64)
        slot_req: list = [None] * self.slots
        results: dict = {}
        for _ in range(max_steps):
            for s in range(self.slots):
                if slot_req[s] is None and queue:
                    req = queue.popleft()
                    slot_req[s] = req
                    lens[s] = 0
                    req.cursor = 0
                    cur_tok[s] = int(req.prompt[0])
            if all(r is None for r in slot_req) and not queue:
                break
            toks = jnp.asarray(cur_tok, jnp.int32)
            step_len = jnp.asarray(np.maximum(lens + 1, 1), jnp.int32)
            logits, cache = self.decode(params, cache, toks, step_len)
            logits = np.asarray(logits)
            for s in range(self.slots):
                req = slot_req[s]
                if req is None:
                    continue
                lens[s] += 1
                req.cursor += 1
                if req.cursor < len(req.prompt):
                    cur_tok[s] = int(req.prompt[req.cursor])
                else:
                    nxt = int(np.argmax(logits[s, : cfg.vocab]))
                    req.out.append(nxt)
                    cur_tok[s] = nxt
                    if len(req.out) >= req.max_new or lens[s] >= self.max_len - 1:
                        results[req.rid] = list(req.out)
                        slot_req[s] = None
        return results
