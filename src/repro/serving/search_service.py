"""Continuous-batching serving front over a ``SearchSession``.

``SearchSession.search`` is a synchronous full-batch call — fine for the
paper's figures, wrong for serving, where queries arrive one at a time and
tail latency is the contract.  ``SearchService`` closes that gap with the
same slot pattern ``serving/engine.py`` proved for LM decode: arriving
single queries enqueue (O(1) deque admission) and each ``step()`` packs up
to ``slots`` of them into ONE fixed-shape device batch — the batch is always
padded to exactly ``slots`` rows, so the jitted search graph compiles once
and every later step hits the jit cache no matter how many requests are
waiting.  Under load, requests that arrive while a batch is in flight are
served together in the next step: the continuous-batching dynamic that
trades a little per-request latency for sustained throughput.

Writes ride the LSM-style delta path (DESIGN.md §6): ``add()`` appends to
the session, whose jax backend keeps its cached main block layout and scans
the new rows from a small delta segment under the same running tau —
inserts no longer re-materialize the corpus, so a mixed read/write workload
keeps serving between merges.

Each completed request carries its own ids/dists, the per-query exactness
certificate (``certified``; from the streaming engine's dropped-estimate
bound, DESIGN.md §4), and the batch's policy stats, so a caller can retry
or degrade per request instead of per batch.

Timing is injectable: by default ``submit``/``step`` stamp
``time.perf_counter()``, but both accept an explicit ``now`` so a
discrete-event driver (benchmarks/bench_serving.py) can replay Poisson
arrivals against measured service times without sleeping through the
arrival process.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.engine import EXTRA_UNCERTIFIED_MASK


@dataclass
class SearchRequest:
    """One in-flight (then completed) query and its per-request telemetry."""

    rid: int
    q: np.ndarray                  # (D,) float32
    t_submit: float
    t_done: float | None = None
    service_s: float | None = None   # wall time of the batch that served it
    batch_size: int = 0              # real (non-pad) requests in that batch
    n_visible: int = 0               # corpus rows visible when served
    ids: np.ndarray | None = None    # (k,) int64
    dists: np.ndarray | None = None  # (k,) float32
    certified: bool | None = None    # per-query exactness certificate
    stats: dict = field(default_factory=dict)   # batch-level policy stats

    @property
    def done(self) -> bool:
        """True once a step has served this request."""
        return self.t_done is not None

    @property
    def latency_s(self) -> float:
        """Submit-to-completion latency (queueing + service)."""
        if self.t_done is None:
            raise ValueError(f"request {self.rid} is still pending")
        return self.t_done - self.t_submit


class SearchService:
    """Continuous-batching query front: ``submit()`` -> ``step()``/``drain()``.

    ``slots`` is the fixed device batch width (pad-to-``slots`` keeps the
    jitted graph static; make it a multiple of the session's
    ``policy.query_chunk`` so one step is a whole number of engine chunks).
    ``k``/``nprobe`` are fixed per service so result shapes stay static too.
    """

    def __init__(self, session, *, slots: int = 16, k: int = 10,
                 nprobe: int = 16, clock=time.perf_counter):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.session = session
        self.slots = slots
        self.k = k
        self.nprobe = nprobe
        self._clock = clock
        self._queue: deque[SearchRequest] = deque()
        self._next_rid = 0
        # service-level counters (bench_serving's headline inputs)
        self.completed = 0
        self.steps = 0
        self.busy_s = 0.0            # wall time spent inside search calls
        self.rows_inserted = 0
        self.insert_s = 0.0          # wall time spent inside add calls
        self.write_modes: dict = {}  # mode -> count (delta/merge/rebuild/...)

    # -- admission -----------------------------------------------------------
    @property
    def pending(self) -> int:
        """Requests admitted but not yet served."""
        return len(self._queue)

    def submit(self, q, *, now: float | None = None) -> SearchRequest:
        """Enqueue one query; returns its (pending) request ticket."""
        q = np.asarray(q, np.float32).reshape(-1)
        if q.shape[0] != self.session.dim:
            raise ValueError(
                f"submit(): query has dimension {q.shape[0]}, but the index "
                f"was built with D={self.session.dim}")
        req = SearchRequest(rid=self._next_rid, q=q,
                            t_submit=self._clock() if now is None else now)
        self._next_rid += 1
        self._queue.append(req)
        return req

    def add(self, Xnew, *, now: float | None = None) -> dict:
        """Insert rows through the session's delta write path; returns
        ``{"rows", "mode", "wall_s"}`` (mode per backends.notify_append)."""
        t0 = time.perf_counter()
        self.session.add(Xnew)
        wall = time.perf_counter() - t0
        mode = self.session.last_write_mode
        rows = int(np.atleast_2d(Xnew).shape[0])
        self.rows_inserted += rows
        self.insert_s += wall
        self.write_modes[mode] = self.write_modes.get(mode, 0) + 1
        return {"rows": rows, "mode": mode, "wall_s": wall}

    # -- serving -------------------------------------------------------------
    def step(self, *, now: float | None = None) -> list[SearchRequest]:
        """Serve ONE fixed-shape batch: pop up to ``slots`` queued requests,
        pad to exactly ``slots`` queries, run one session search, and fill
        each served request (ids/dists/certificate/stats + timestamps).

        With ``now`` given (simulated time), completions are stamped
        ``now + measured_service_wall``; otherwise the real clock is used.
        Returns the served requests ([] when the queue was empty)."""
        if not self._queue:
            return []
        batch = [self._queue.popleft()
                 for _ in range(min(self.slots, len(self._queue)))]
        Q = np.stack([r.q for r in batch])
        if len(batch) < self.slots:
            # pad with a replay of the last real query: static (slots, D)
            # shape -> the jitted graph compiles once for the service
            Q = np.concatenate(
                [Q, np.broadcast_to(Q[-1], (self.slots - len(batch),
                                            Q.shape[1]))])
        t0 = time.perf_counter()
        res = self.session.search(Q, self.k, nprobe=self.nprobe)
        wall = time.perf_counter() - t0
        t_done = (now + wall) if now is not None else self._clock()
        mask = res.stats.extra.get(EXTRA_UNCERTIFIED_MASK)
        stats = {key: v for key, v in res.stats.extra.items()
                 if np.isscalar(v)}
        n_visible = self.session.n
        for j, req in enumerate(batch):
            req.ids = res.ids[j]
            req.dists = res.dists[j]
            req.certified = None if mask is None else bool(~mask[j])
            req.stats = stats
            req.t_done = t_done
            req.service_s = wall
            req.batch_size = len(batch)
            req.n_visible = n_visible
        self.steps += 1
        self.completed += len(batch)
        self.busy_s += wall
        return batch

    def drain(self, *, now: float | None = None) -> list[SearchRequest]:
        """Serve until the queue is empty; in simulated time consecutive
        batches complete back-to-back (each step starts when the previous
        finished).  Returns all served requests in completion order."""
        served: list[SearchRequest] = []
        t = now
        while self._queue:
            batch = self.step(now=t)
            if t is not None and batch:
                t = batch[0].t_done
            served.extend(batch)
        return served
