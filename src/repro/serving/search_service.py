"""Continuous-batching serving front over a ``SearchSession``.

``SearchSession.search`` is a synchronous full-batch call — fine for the
paper's figures, wrong for serving, where queries arrive one at a time and
tail latency is the contract.  ``SearchService`` closes that gap with the
same slot pattern ``serving/engine.py`` proved for LM decode: arriving
single queries enqueue (O(1) deque admission) and each ``step()`` packs up
to ``slots`` of them into ONE fixed-shape device batch — the batch is always
padded to exactly ``slots`` rows, so the jitted search graph compiles once
and every later step hits the jit cache no matter how many requests are
waiting.  Under load, requests that arrive while a batch is in flight are
served together in the next step: the continuous-batching dynamic that
trades a little per-request latency for sustained throughput.

Overload protection (DESIGN.md §7) keeps that contract under bursts the
device cannot absorb.  Admission is bounded: with ``max_queue`` set, a full
queue either rejects the new request (``admission="reject"``) or sheds the
oldest queued one to make room (``admission="shed_oldest"``) — either way
the victim's ticket resolves with ``status="shed"`` instead of silently
growing the queue.  Every request may carry a ``deadline_s`` budget (per
request or the service default): expire while *queued* and the ticket
resolves ``status="timeout"`` without ever touching the device; reach the
device with little budget left and the batch runs as an *anytime* search
(``SearchSession.search(deadline_s=...)``) that returns the running top-k
as a partial result (``coverage < 1``, ``certified=False``).  A device-step
exception (e.g. an injected ``testing.faults.FaultError``) fails only the
batch that hit it — its requests resolve ``status="failed"`` and the
service keeps serving.  ``health()`` snapshots queue depth, an EWMA of the
windowed p99 latency, the shed/timeout/partial/uncertified/failure
counters, and — when the session is guarded (DESIGN.md §9) — the circuit
breaker's state and drift/audit EWMAs; every submitted request is
accounted for by exactly one of
``completed + shed + timeouts + failures + pending``.

Writes ride the LSM-style delta path (DESIGN.md §6): ``add()`` appends to
the session, whose jax backend keeps its cached main block layout and scans
the new rows from a small delta segment under the same running tau —
inserts no longer re-materialize the corpus, so a mixed read/write workload
keeps serving between merges.

Each completed request carries its own ids/dists, the per-query exactness
certificate (``certified``; from the streaming engine's dropped-estimate
bound, DESIGN.md §4), its scan ``coverage``, and the batch's policy stats,
so a caller can retry or degrade per request instead of per batch.

Timing is injectable: by default ``submit``/``step`` stamp
``time.perf_counter()``, but both accept an explicit ``now`` so a
discrete-event driver (benchmarks/bench_serving.py, bench_robustness.py)
can replay Poisson arrivals against measured service times without sleeping
through the arrival process.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.engine import EXTRA_COVERAGE, EXTRA_UNCERTIFIED_MASK

#: Terminal ticket states (``SearchRequest.status``); "pending" is the only
#: non-terminal one.  Exactly one terminal state per submitted request.
REQUEST_STATUSES = ("pending", "done", "timeout", "shed", "failed")
ADMISSION_POLICIES = ("reject", "shed_oldest")


@dataclass
class SearchRequest:
    """One in-flight (then resolved) query and its per-request telemetry."""

    rid: int
    q: np.ndarray                  # (D,) float32
    t_submit: float
    t_deadline: float | None = None  # absolute; None = no budget
    status: str = "pending"
    t_done: float | None = None
    service_s: float | None = None   # wall time of the batch that served it
    batch_size: int = 0              # real (non-pad) requests in that batch
    n_visible: int = 0               # corpus rows visible when served
    ids: np.ndarray | None = None    # (k,) int64
    dists: np.ndarray | None = None  # (k,) float32
    certified: bool | None = None    # per-query exactness certificate
    coverage: float | None = None    # scanned fraction (anytime; 1.0 = full)
    error: str | None = None         # set when status == "failed"
    stats: dict = field(default_factory=dict)   # batch-level policy stats

    @property
    def done(self) -> bool:
        """True once this request was actually served with results."""
        return self.status == "done"

    @property
    def resolved(self) -> bool:
        """True once the ticket reached any terminal state (served, timed
        out, shed, or failed) — i.e. waiting on it is over."""
        return self.status != "pending"

    @property
    def latency_s(self) -> float:
        """Submit-to-resolution latency (queueing + service)."""
        if self.t_done is None:
            raise ValueError(f"request {self.rid} is still pending")
        return self.t_done - self.t_submit


class SearchService:
    """Continuous-batching query front: ``submit()`` -> ``step()``/``drain()``.

    ``slots`` is the fixed device batch width (pad-to-``slots`` keeps the
    jitted graph static; make it a multiple of the session's
    ``policy.query_chunk`` so one step is a whole number of engine chunks).
    ``k``/``nprobe`` are fixed per service so result shapes stay static too.

    Robustness knobs (DESIGN.md §7): ``max_queue`` bounds admission (None =
    unbounded, the pre-robustness behavior), ``admission`` picks the full-
    queue policy (``"reject"`` the newcomer or ``"shed_oldest"`` victim),
    and ``deadline_s`` is the default per-request budget — queued past it
    resolves ``timeout``, served near it runs as an anytime partial scan.
    """

    def __init__(self, session, *, slots: int = 16, k: int = 10,
                 nprobe: int = 16, clock=time.perf_counter,
                 max_queue: int | None = None, admission: str = "reject",
                 deadline_s: float | None = None):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of {ADMISSION_POLICIES}, "
                             f"got {admission!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, got {max_queue}")
        if deadline_s is not None and deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0 or None, got {deadline_s}")
        self.session = session
        self.slots = slots
        self.k = k
        self.nprobe = nprobe
        self.max_queue = max_queue
        self.admission = admission
        self.deadline_s = deadline_s
        self._clock = clock
        self._queue: deque[SearchRequest] = deque()
        self._next_rid = 0
        # service-level counters (bench_serving's headline inputs)
        self.submitted = 0
        self.completed = 0
        self.steps = 0
        self.busy_s = 0.0            # wall time spent inside search calls
        self.rows_inserted = 0
        self.insert_s = 0.0          # wall time spent inside add calls
        self.write_modes: dict = {}  # mode -> count (delta/merge/rebuild/...)
        # robustness counters (DESIGN.md §7; health() snapshots these)
        self.shed = 0                # admission victims (reject or shed_oldest)
        self.timeouts = 0            # budget expired while queued
        self.partials = 0            # served with coverage < 1.0
        self.uncertified = 0         # served with a withdrawn certificate
        self.failures = 0            # requests lost to a device-step error
        self._lat_window: deque[float] = deque(maxlen=128)
        self._p99_ewma: float | None = None

    # -- admission -----------------------------------------------------------
    @property
    def pending(self) -> int:
        """Requests admitted but not yet served."""
        return len(self._queue)

    def submit(self, q, *, now: float | None = None,
               deadline_s: float | None = None) -> SearchRequest:
        """Enqueue one query; returns its request ticket.

        The ticket usually comes back ``pending`` (serve it with ``step``/
        ``drain``), but under a full bounded queue with
        ``admission="reject"`` it resolves immediately as ``shed`` — check
        ``req.resolved``.  ``deadline_s`` overrides the service default
        budget for this request."""
        q = np.asarray(q, np.float32).reshape(-1)
        if q.shape[0] != self.session.dim:
            raise ValueError(
                f"submit(): query has dimension {q.shape[0]}, but the index "
                f"was built with D={self.session.dim}")
        if not np.isfinite(q).all():
            raise ValueError(
                "submit(): query contains NaN/Inf values; distances to "
                "non-finite queries are meaningless and would poison the "
                "whole batch's running top-k threshold")
        if deadline_s is not None and deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0 or None, got {deadline_s}")
        t = self._clock() if now is None else now
        budget = deadline_s if deadline_s is not None else self.deadline_s
        req = SearchRequest(
            rid=self._next_rid, q=q, t_submit=t,
            t_deadline=None if budget is None else t + budget)
        self._next_rid += 1
        self.submitted += 1
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            if self.admission == "reject":
                req.status = "shed"
                self.shed += 1
                return req            # resolved, never enqueued
            victim = self._queue.popleft()     # shed_oldest
            victim.status = "shed"
            victim.t_done = t
            self.shed += 1
        self._queue.append(req)
        return req

    def add(self, Xnew, *, now: float | None = None) -> dict:
        """Insert rows through the session's delta write path; returns
        ``{"rows", "mode", "wall_s"}`` (mode per backends.notify_append)."""
        t0 = time.perf_counter()
        self.session.add(Xnew)
        wall = time.perf_counter() - t0
        mode = self.session.last_write_mode
        rows = int(np.atleast_2d(Xnew).shape[0])
        self.rows_inserted += rows
        self.insert_s += wall
        self.write_modes[mode] = self.write_modes.get(mode, 0) + 1
        return {"rows": rows, "mode": mode, "wall_s": wall}

    # -- serving -------------------------------------------------------------
    def _expire_queued(self, t: float) -> list[SearchRequest]:
        """Resolve every queued request whose budget has already expired as
        ``timeout`` (it never reaches the device — the anytime engines would
        only burn a block group on it)."""
        expired: list[SearchRequest] = []
        if not self._queue:
            return expired
        alive: deque[SearchRequest] = deque()
        for req in self._queue:
            if req.t_deadline is not None and t > req.t_deadline:
                req.status = "timeout"
                req.t_done = t
                self.timeouts += 1
                self._observe_latency(req)
                expired.append(req)
            else:
                alive.append(req)
        self._queue = alive
        return expired

    def _observe_latency(self, req: SearchRequest) -> None:
        self._lat_window.append(req.latency_s)
        w = sorted(self._lat_window)
        p99 = w[min(len(w) - 1, int(0.99 * len(w)))]
        self._p99_ewma = (p99 if self._p99_ewma is None
                          else 0.8 * self._p99_ewma + 0.2 * p99)

    def _dispatch(self, Q, deadline_s):
        """One device dispatch: run the session search on the padded batch
        and return ``(result, service_wall_s)``.

        This is the replica tier's override point (serving.replica,
        DESIGN.md §10): ``ReplicatedService`` swaps in retry/hedge/fan-out
        routing and a *virtual* wall (the simulated timeline of those
        dispatches), while everything around it — ticket admission, padding,
        timeout expiry, accounting — stays this class's.  A raised exception
        fails the batch; raisers may attach ``wall_s`` to the exception to
        charge the time the failure consumed."""
        t0 = time.perf_counter()
        res = self.session.search(Q, self.k, nprobe=self.nprobe,
                                  deadline_s=deadline_s)
        return res, time.perf_counter() - t0

    def _visible_rows(self) -> int:
        """Corpus rows visible to a batch served now (replica tier:
        aggregate over shards)."""
        return int(self.session.n)

    def step(self, *, now: float | None = None) -> list[SearchRequest]:
        """Serve ONE fixed-shape batch: resolve budget-expired queued
        requests as ``timeout``, pop up to ``slots`` survivors, pad to
        exactly ``slots`` queries, run one session search (anytime-capped at
        the tightest member budget), and fill each served request
        (ids/dists/certificate/coverage/stats + timestamps).

        With ``now`` given (simulated time), completions are stamped
        ``now + measured_service_wall``; otherwise the real clock is used.
        Returns every request *resolved* by this step — served ones plus
        any that timed out in the queue ([] when nothing was pending)."""
        t_now = self._clock() if now is None else now
        resolved = self._expire_queued(t_now)
        if not self._queue:
            return resolved
        batch = [self._queue.popleft()
                 for _ in range(min(self.slots, len(self._queue)))]
        Q = np.stack([r.q for r in batch])
        if len(batch) < self.slots:
            # pad with a replay of the last real query: static (slots, D)
            # shape -> the jitted graph compiles once for the service
            Q = np.concatenate(
                [Q, np.broadcast_to(Q[-1], (self.slots - len(batch),
                                            Q.shape[1]))])
        # the batch scans together, so its anytime budget is the tightest
        # member's remaining budget (members with no budget impose none)
        budgets = [r.t_deadline - t_now for r in batch
                   if r.t_deadline is not None]
        deadline = max(min(budgets), 1e-4) if budgets else None
        t0 = time.perf_counter()
        try:
            res, wall = self._dispatch(Q, deadline)
        except Exception as exc:          # noqa: BLE001 — fail the batch,
            wall = getattr(exc, "wall_s", None)  # not the service (§7)
            if wall is None:
                wall = time.perf_counter() - t0
            t_done = (now + wall) if now is not None else self._clock()
            for req in batch:
                req.status = "failed"
                req.error = f"{type(exc).__name__}: {exc}"
                req.t_done = t_done
                req.service_s = wall
                req.batch_size = len(batch)
                self._observe_latency(req)
            self.failures += len(batch)
            self.steps += 1
            self.busy_s += wall
            return resolved + batch
        t_done = (now + wall) if now is not None else self._clock()
        mask = res.stats.extra.get(EXTRA_UNCERTIFIED_MASK)
        cov = res.stats.extra.get(EXTRA_COVERAGE)
        stats = {key: v for key, v in res.stats.extra.items()
                 if np.isscalar(v)}
        n_visible = self._visible_rows()
        for j, req in enumerate(batch):
            req.ids = res.ids[j]
            req.dists = res.dists[j]
            req.certified = None if mask is None else bool(~mask[j])
            if req.certified is False:
                self.uncertified += 1
            req.coverage = None if cov is None else float(cov[j])
            if req.coverage is not None and req.coverage < 1.0:
                self.partials += 1
            req.stats = stats
            req.status = "done"
            req.t_done = t_done
            req.service_s = wall
            req.batch_size = len(batch)
            req.n_visible = n_visible
            self._observe_latency(req)
        self.steps += 1
        self.completed += len(batch)
        self.busy_s += wall
        return resolved + batch

    def drain(self, *, now: float | None = None) -> list[SearchRequest]:
        """Serve until the queue is empty; in simulated time consecutive
        batches complete back-to-back (each step starts when the previous
        finished).  Budget-expired requests resolve ``timeout`` instead of
        being served, so drain always terminates even mid-overload.
        Returns all resolved requests in resolution order."""
        served: list[SearchRequest] = []
        t = now
        while self._queue:
            batch = self.step(now=t)
            if t is not None and batch:
                t = max(r.t_done for r in batch)
            served.extend(batch)
        return served

    # -- observability --------------------------------------------------------
    def health(self) -> dict:
        """Snapshot of the service's load state (DESIGN.md §7): queue depth,
        EWMA of the windowed p99 request latency (seconds; None until the
        first resolution), and the full request-accounting counters.
        ``submitted == completed + shed + timeouts + failures + pending``
        holds at every quiescent point (``partials`` and ``uncertified``
        sub-count completed requests — coverage < 1.0 and withdrawn
        exactness certificates respectively).

        When the session carries a guardrail (``SchedulePolicy(guardrails=
        ...)``, DESIGN.md §9), the snapshot also reports its breaker state
        and sentinel/audit EWMAs under ``breaker_state`` / ``drift_score``
        / ``audit_recall`` / ``demoted_batches``."""
        h = {
            "queue_depth": len(self._queue),
            "p99_ewma_s": self._p99_ewma,
            "submitted": self.submitted,
            "completed": self.completed,
            "shed": self.shed,
            "timeouts": self.timeouts,
            "partials": self.partials,
            "uncertified": self.uncertified,
            "failures": self.failures,
            "steps": self.steps,
            "busy_s": self.busy_s,
            "rows_inserted": self.rows_inserted,
        }
        g = self.session.guardrails() if hasattr(self.session, "guardrails") \
            else None
        if g is not None:
            h["breaker_state"] = g["state"]
            h["drift_score"] = g["drift_score"]
            h["audit_recall"] = g["audit_recall"]
            h["demoted_batches"] = g["demoted_batches"]
        wal = getattr(self.session, "wal", None)
        if wal is not None:
            h["wal_bytes"] = wal.total_bytes()
        return h
