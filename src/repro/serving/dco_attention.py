"""DCO-screened attention (beyond-paper): the paper's two-stage pruning
applied to long-context decode.

Attention at decode IS a vector similarity search: the query vector scans
every cached key for the largest inner products.  We apply the DCO playbook
(DESIGN.md §4): keys are cached in a PCA-rotated basis (rotation R fitted on
key statistics, distance/IP-preserving); stage 1 computes PARTIAL scores on
the leading d1 rotated dims for all S cached keys; the top-C candidates by
partial score proceed to stage 2 (exact scores on all dims) and softmax is
taken over those C only.

Per-step HBM traffic drops from S*hd to S*d1 + C*hd bytes — the same
bytes-currency win as the retrieval engine, and the reason this composes
well with MLA (the latent c_kv is already the 'rotated' compressed basis).

This is APPROXIMATE attention (softmax mass outside the top-C is dropped);
tests/test_dco_attention.py bounds the error against exact attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def fit_key_rotation(keys: np.ndarray) -> np.ndarray:
    """PCA rotation (hd, hd) from sampled key vectors (n, hd)."""
    k = np.asarray(keys, np.float64)
    k = k - k.mean(0)
    cov = k.T @ k / max(1, k.shape[0] - 1)
    evals, evecs = np.linalg.eigh(cov)
    return np.ascontiguousarray(evecs[:, ::-1]).astype(np.float32)


@functools.partial(jax.jit, static_argnames=("d1", "cap"))
def dco_decode_attention(q, k_rot_cache, v_cache, rot, cur_len, *,
                         d1: int = 32, cap: int = 512, scale=None):
    """q (B, H, hd); k_rot_cache (B, S, Hkv, hd) keys ALREADY in the rotated
    basis; v_cache (B, S, Hkv, hd); rot (hd, hd).  Returns (B, H, hd).
    GQA: H = G * Hkv."""
    B, H, hd = q.shape
    S, Hkv = k_rot_cache.shape[1], k_rot_cache.shape[2]
    G = H // Hkv
    C = min(cap, S)
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    q_rot = jnp.einsum("bhd,de->bhe", q, rot).reshape(B, Hkv, G, hd)
    # ---- stage 1: partial scores on leading d1 rotated dims ---------------
    s1 = jnp.einsum("bhgd,bshd->bhgs", q_rot[..., :d1],
                    k_rot_cache[..., :d1],
                    preferred_element_type=jnp.float32)
    pos_ok = jnp.arange(S)[None, None, None, :] < jnp.broadcast_to(
        jnp.asarray(cur_len), (B,))[:, None, None, None]
    s1 = jnp.where(pos_ok, s1, -jnp.inf)
    # ---- top-C screening ---------------------------------------------------
    _, idx = jax.lax.top_k(s1, C)                       # (B, Hkv, G, C)
    # ---- stage 2: exact scores for survivors -------------------------------
    bidx = jnp.arange(B)[:, None, None, None]
    hidx = jnp.arange(Hkv)[None, :, None, None]
    k_sel = k_rot_cache[bidx, idx, hidx]                # (B, Hkv, G, C, hd)
    v_sel = v_cache[bidx, idx, hidx]
    s2 = jnp.einsum("bhgd,bhgcd->bhgc", q_rot, k_sel,
                    preferred_element_type=jnp.float32) * scale
    alive = jnp.take_along_axis(jnp.isfinite(s1), idx, axis=-1)
    s2 = jnp.where(alive, s2, -jnp.inf)
    p = jax.nn.softmax(s2, axis=-1)
    out = jnp.einsum("bhgc,bhgcd->bhgd", p.astype(v_sel.dtype), v_sel,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


def exact_decode_attention(q, k_cache, v_cache, cur_len, *, scale=None):
    """Oracle for the tests: full softmax attention over the cache."""
    B, H, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    ok = jnp.arange(S)[None, None, None, :] < jnp.broadcast_to(
        jnp.asarray(cur_len), (B,))[:, None, None, None]
    s = jnp.where(ok, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)
