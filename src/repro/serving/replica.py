"""Fault-tolerant replicated serving tier (DESIGN.md §10).

The paper's verdict — DCO performance is unstable across hardware and
workloads — lands hardest in the deployment the "Bang for the Buck"
follow-up measures: noisy multi-tenant cloud hosts, where slow and dead
replicas are the norm rather than the exception.  PR 7/9 hardened a
*single* session (deadlines, shedding, WAL, drift breakers); this module
is the layer above it: ``ReplicatedService`` wraps R replica
``SearchSession``\\ s behind the exact submit/step/drain/health ticket
lifecycle of ``SearchService`` and turns replica faults into bounded,
*flagged* degradation instead of wrong answers or hung requests.

Two layouts, one service:

``mode="replicate"``
    every replica holds the full corpus.  Batches route round-robin over
    healthy replicas; a failed dispatch **retries** on a different replica
    under capped exponential backoff with deterministic jitter (injectable
    RNG), and a slow primary is **hedged** — when its measured wall
    exceeds an adaptive delay derived from the fleet's best windowed-p99
    EWMA, the batch is re-dispatched to another healthy replica and the
    first (virtual-timeline) finisher wins, with hedge-rate and win/loss
    telemetry in ``health()``.

``mode="shard"``
    each replica holds a contiguous row range (the PR 2 partition-major
    idea lifted to whole sessions); every batch fans out to all live
    shards and the per-shard top-k merge re-bases local ids by the shard's
    row offset.  When a shard stays dead through its retries, the batch is
    answered from the *surviving* shards — the PR 7 anytime semantics
    extended from temporal to spatial partial coverage: per-query
    ``coverage`` becomes the fraction of corpus rows actually visited,
    every query's exactness certificate is withdrawn via
    ``uncertified_mask`` (an unvisited shard may hold a true neighbor),
    and the batch is flagged ``degraded`` in its stats and counted in
    ``health()`` — while the accounting invariant
    ``submitted == completed + shed + timeouts + failures + pending``
    holds exactly (degraded completions are completions).

Health-gated routing reuses PR 9's breaker state machine
(``core.guardrails.BreakerCore``) per replica: ``eject_after`` consecutive
dispatch failures flip a replica closed -> open (ejected from routing);
after ``probe_after`` quiet rounds it goes half_open and is probed with
real traffic; ``promote_after`` consecutive probe successes re-admit it
(closed), one failure re-ejects it.  When *every* replica is ejected the
service keeps probing rather than refusing — and only when all retries
against all replicas fail does the batch fail (the ticket lifecycle
absorbs it as ``status="failed"``; the service survives).

Timing is *virtual* where it must be replay-exact: backoff and hedge
delays are charged to the batch's service wall (the same simulated
timeline ``bench_robustness`` replays Poisson arrivals on) rather than
slept, the hedge race is resolved on measured walls
(``min(primary, delay + secondary)``), and both the jitter RNG and the
per-dispatch timer are injectable — two chaos runs with the same seeds
and timer produce identical routing, hedging, and timelines.  Pass
``sleeper=time.sleep`` to make live-mode backoff actually wait.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.engine import (EXTRA_COVERAGE, EXTRA_DEGRADED, EXTRA_HEDGED,
                               EXTRA_REPLICA, EXTRA_UNCERTIFIED_MASK,
                               EXTRA_UNCERTIFIED_QUERIES, ScanStats)
from repro.core.guardrails import BreakerCore
from repro.serving.search_service import SearchService
from repro.testing import faults

REPLICA_MODES = ("replicate", "shard")


class ReplicaDispatchError(RuntimeError):
    """Every routable replica (or every shard) failed a batch, retries
    included.  Carries ``wall_s`` — the virtual time the failed attempts
    consumed — so the serving loop charges the failure honestly."""

    def __init__(self, msg: str, wall_s: float = 0.0):
        super().__init__(msg)
        self.wall_s = float(wall_s)


@dataclasses.dataclass(frozen=True)
class ReplicaPolicy:
    """Static knobs of the replicated tier (frozen: safe to share).

    ``max_retries``       extra dispatch attempts per batch after the
                          first fails (replicate: each on a different
                          replica; shard: against the same shard).
    ``backoff_base_s``    backoff before retry attempt i is
                          ``min(cap, base * 2**(i-1)) * (1 + jitter*u)``,
                          u ~ U[0,1) from the injectable RNG — capped
                          exponential with deterministic jitter.
    ``backoff_cap_s``     the cap above.
    ``jitter``            the jitter fraction above (0 = none).
    ``hedge``             arm hedged requests (replicate mode only).
    ``hedge_factor``      hedge when the primary's wall exceeds
                          ``hedge_factor * min windowed-p99 EWMA`` over
                          routable replicas — adaptive: a uniformly slow
                          fleet hedges rarely, one straggler hedges often.
    ``hedge_min_delay_s`` floor on that adaptive delay (keeps cold-start
                          p99 estimates from hedging everything).
    ``eject_after``       consecutive dispatch failures before a replica
                          is ejected (closed -> open).
    ``probe_after``       quiet rounds an ejected replica waits before
                          half-open probing begins.
    ``promote_after``     consecutive successful probes before
                          re-admission (half_open -> closed).
    ``seed``              jitter RNG seed (replay-exact chaos runs).
    """

    max_retries: int = 2
    backoff_base_s: float = 0.02
    backoff_cap_s: float = 0.5
    jitter: float = 0.25
    hedge: bool = True
    hedge_factor: float = 2.0
    hedge_min_delay_s: float = 0.005
    eject_after: int = 2
    probe_after: int = 3
    promote_after: int = 2
    seed: int = 0


class ReplicaState:
    """One replica's runtime: its session, its row range, its breaker, and
    its latency/outcome telemetry (the ``health()`` per-replica row)."""

    def __init__(self, idx: int, session: SearchSession, id_offset: int = 0):
        self.idx = idx
        self.session = session
        self.id_offset = int(id_offset)   # global id of the shard's row 0
        self.rows = int(session.n)        # rows this replica serves
        self.breaker = BreakerCore()
        self.consecutive_failures = 0
        self.promote_streak = 0           # successes while half_open
        self.dispatches = 0
        self.served = 0
        self.failures = 0
        self.probes = 0                   # dispatches served while half_open
        self.rounds = 0                   # routing rounds observed
        self._lat_window: deque = deque(maxlen=64)
        self.p99_ewma: float | None = None

    @property
    def state(self) -> str:
        return self.breaker.state

    def observe(self, wall: float) -> None:
        """Fold one successful dispatch wall into the windowed p99 EWMA
        (the hedge-delay input)."""
        self._lat_window.append(float(wall))
        w = sorted(self._lat_window)
        p99 = w[min(len(w) - 1, int(0.99 * len(w)))]
        self.p99_ewma = (p99 if self.p99_ewma is None
                         else 0.8 * self.p99_ewma + 0.2 * p99)

    def report(self) -> dict:
        """The per-replica ``health()`` row."""
        return {
            "idx": self.idx,
            "state": self.state,
            "rows": self.rows,
            "id_offset": self.id_offset,
            "p99_ewma_s": self.p99_ewma,
            "dispatches": self.dispatches,
            "served": self.served,
            "failures": self.failures,
            "consecutive_failures": self.consecutive_failures,
            "probes": self.probes,
            "transitions": list(self.breaker.transitions),
        }


class ReplicatedService(SearchService):
    """R-replica serving front behind the ``SearchService`` lifecycle.

    Construction takes the replica sessions (same D; ``mode="shard"``
    additionally assumes they partition one corpus in contiguous row
    ranges — use :func:`open_replicated` to build both layouts from a
    single corpus).  All ``SearchService`` knobs (slots/k/max_queue/
    admission/deadline_s/clock) apply unchanged; the tier only overrides
    *dispatch* — routing, retries, hedging, fan-out/merge — plus ``add()``
    (write fan-out) and ``health()`` (replica telemetry).

    ``rng`` injects the jitter RNG (default: seeded from the policy);
    ``timer`` injects a per-dispatch wall override ``timer(replica_idx,
    measured_wall) -> wall`` so chaos tests replace measured time with a
    deterministic timeline; ``sleeper`` (e.g. ``time.sleep``) makes
    live-mode backoff actually wait instead of only charging the virtual
    wall.
    """

    def __init__(self, sessions, *, mode: str = "replicate",
                 replica_policy: ReplicaPolicy | None = None,
                 rng=None, timer=None, sleeper=None, **kwargs):
        sessions = list(sessions)
        if not sessions:
            raise ValueError("ReplicatedService needs at least one session")
        if mode not in REPLICA_MODES:
            raise ValueError(
                f"mode must be one of {REPLICA_MODES}, got {mode!r}")
        dims = {int(s.dim) for s in sessions}
        if len(dims) != 1:
            raise ValueError(
                f"replica sessions disagree on D: {sorted(dims)}")
        super().__init__(sessions[0], **kwargs)
        self.mode = mode
        self.rpolicy = replica_policy or ReplicaPolicy()
        self._rng = rng if rng is not None \
            else np.random.default_rng(self.rpolicy.seed)
        self._timer = timer
        self._sleeper = sleeper
        offsets = np.cumsum([0] + [int(s.n) for s in sessions[:-1]])
        self.replicas = [
            ReplicaState(i, s, offsets[i] if mode == "shard" else 0)
            for i, s in enumerate(sessions)]
        self._rr = 0                      # round-robin cursor
        # tier counters (health(); accounting stays the base invariant)
        self.retries = 0
        self.hedges = 0
        self.hedge_wins = 0
        self.hedge_losses = 0
        self.degraded = 0                 # completed requests with lost shards

    # -- routing -------------------------------------------------------------
    def _tick_round(self) -> None:
        """One routing round: every breaker dwells one step, and ejected
        replicas that served their ``probe_after`` quiet rounds move to
        half_open (probed with real traffic from the next pick on)."""
        for rs in self.replicas:
            rs.rounds += 1
            rs.breaker.tick()
            if rs.state == "open" \
                    and rs.breaker.dwell >= self.rpolicy.probe_after:
                rs.breaker.transition("half_open", "probe window open",
                                      at=rs.rounds)

    def _pick(self, exclude=()) -> ReplicaState | None:
        """Next replica to try: round-robin over routable replicas —
        closed and half_open alike, so probes ride real traffic instead of
        starving behind healthy peers — then, desperation (all ejected),
        the open replica that has waited longest.  ``None`` once
        ``exclude`` covers everyone."""
        order = [self.replicas[(self._rr + j) % len(self.replicas)]
                 for j in range(len(self.replicas))]
        live = [rs for rs in order
                if rs.state != "open" and rs.idx not in exclude]
        if live:
            self._rr = (live[0].idx + 1) % len(self.replicas)
            return live[0]
        left = [rs for rs in self.replicas if rs.idx not in exclude]
        return max(left, key=lambda rs: rs.breaker.dwell) if left else None

    def _backoff(self, attempt: int) -> float:
        """Virtual seconds charged before retry ``attempt`` (1-based):
        capped exponential with deterministic jitter from the injected
        RNG."""
        pol = self.rpolicy
        base = min(pol.backoff_cap_s,
                   pol.backoff_base_s * (2.0 ** (attempt - 1)))
        delay = base * (1.0 + pol.jitter * float(self._rng.random()))
        if self._sleeper is not None:
            self._sleeper(delay)
        return delay

    def _note_failure(self, rs: ReplicaState, exc: Exception) -> None:
        rs.failures += 1
        rs.consecutive_failures += 1
        rs.promote_streak = 0
        if rs.state == "half_open":
            rs.breaker.transition(
                "open", f"probe failed ({type(exc).__name__})", at=rs.rounds)
        elif rs.state == "closed" \
                and rs.consecutive_failures >= self.rpolicy.eject_after:
            rs.breaker.transition(
                "open", f"ejected: {rs.consecutive_failures} consecutive "
                f"failures ({type(exc).__name__})", at=rs.rounds)

    def _note_success(self, rs: ReplicaState, wall: float) -> None:
        rs.served += 1
        rs.consecutive_failures = 0
        rs.observe(wall)
        if rs.state == "half_open":
            rs.probes += 1
            rs.promote_streak += 1
            if rs.promote_streak >= self.rpolicy.promote_after:
                rs.breaker.transition(
                    "closed", f"re-admitted: {rs.promote_streak} probe "
                    "successes", at=rs.rounds)
        elif rs.state == "open":      # desperation probe paid off
            rs.breaker.transition("half_open", "desperation probe succeeded",
                                  at=rs.rounds)

    # -- one replica dispatch ------------------------------------------------
    def _replica_search(self, rs: ReplicaState, Q, deadline_s):
        """One dispatch against one replica: fault hooks first (a dead
        replica fails before touching the device, like a broken
        connection), then the real search.  Returns ``(result, wall)``;
        raisers carry ``wall_s``.  The wall is measured, then overridden
        by the injected ``timer`` (determinism), then charged the
        slow-replica fault stall (virtual, never slept)."""
        plan = faults.active(rs.session.policy)
        rs.dispatches += 1
        t0 = time.perf_counter()
        try:
            faults.check_replica(plan, rs.idx)
            res = rs.session.search(Q, self.k, nprobe=self.nprobe,
                                    deadline_s=deadline_s)
        except Exception as exc:
            if not hasattr(exc, "wall_s"):
                exc.wall_s = time.perf_counter() - t0
            raise
        wall = time.perf_counter() - t0
        if self._timer is not None:
            wall = float(self._timer(rs.idx, wall))
        wall += faults.replica_delay(plan, rs.idx)
        return res, wall

    # -- dispatch: replicate mode --------------------------------------------
    def _dispatch_replicate(self, Q, deadline_s):
        pol = self.rpolicy
        total = 0.0
        tried: list[int] = []
        last: Exception | None = None
        for attempt in range(pol.max_retries + 1):
            rs = self._pick(exclude=tried)
            if rs is None:
                break
            if attempt > 0:
                self.retries += 1
                total += self._backoff(attempt)
            try:
                res, w = self._replica_search(rs, Q, deadline_s)
            except Exception as exc:          # noqa: BLE001 — any dispatch
                self._note_failure(rs, exc)   # error means try elsewhere
                total += getattr(exc, "wall_s", 0.0)
                tried.append(rs.idx)
                last = exc
                continue
            self._note_success(rs, w)
            winner, served_w, hedged = rs, w, 0.0
            if pol.hedge:
                hres = self._maybe_hedge(rs, res, w, Q, deadline_s,
                                         exclude=tried + [rs.idx])
                if hres is not None:
                    res, winner, served_w, hedged = hres
            total += served_w
            res.stats.extra[EXTRA_REPLICA] = float(winner.idx)
            res.stats.extra[EXTRA_HEDGED] = hedged
            res.stats.extra[EXTRA_DEGRADED] = 0.0
            return res, total
        raise ReplicaDispatchError(
            f"all replica dispatch attempts failed (tried {tried or 'none'}"
            f" of {len(self.replicas)} replicas, last error: "
            f"{type(last).__name__ if last else 'no routable replica'}"
            f"{f': {last}' if last else ''})", wall_s=total)

    def _fleet_p99(self) -> float | None:
        """The hedge-delay input: the *fastest* routable replica's
        windowed-p99 EWMA.  Keyed to the fleet rather than the primary's
        own history — a consistent straggler's own p99 already contains
        its slowness, so self-relative hedging would never fire exactly
        when hedging pays most.  ``None`` until any replica has data."""
        vals = [rs.p99_ewma for rs in self.replicas
                if rs.p99_ewma is not None and rs.state != "open"]
        return min(vals) if vals else None

    def _maybe_hedge(self, primary: ReplicaState, res, w: float,
                     Q, deadline_s, *, exclude):
        """Hedge a slow primary: if its wall ``w`` exceeded the adaptive
        delay (``hedge_factor`` x the fleet's best p99 EWMA, floored),
        race a duplicate on another healthy replica and take the
        virtual-timeline winner (``min(w, delay + secondary_wall)``).
        Returns ``(result, winner, served_wall, 1.0)`` or ``None`` when no
        hedge fired.

        The race is resolved *post hoc* on measured walls: both dispatches
        run to completion (in-process sessions are synchronous), but the
        timeline charged to the ticket is exactly what a concurrent race
        would produce, and the telemetry (hedges / wins / losses) is what
        an operator tunes ``hedge_factor`` by."""
        p99 = self._fleet_p99()
        if p99 is None:
            return None                   # cold start: no estimate yet
        delay = max(self.rpolicy.hedge_min_delay_s,
                    self.rpolicy.hedge_factor * p99)
        if w <= delay:
            return None
        other = self._pick(exclude=exclude)
        if other is None or other.state == "open":
            return None                   # nobody healthy to race
        self.hedges += 1
        try:
            res2, w2 = self._replica_search(other, Q, deadline_s)
        except Exception as exc:          # noqa: BLE001 — a failed hedge
            self._note_failure(other, exc)   # never hurts the primary win
            self.hedge_losses += 1
            return res, primary, w, 1.0
        self._note_success(other, w2)
        if delay + w2 < w:
            self.hedge_wins += 1
            return res2, other, delay + w2, 1.0
        self.hedge_losses += 1
        return res, primary, w, 1.0

    # -- dispatch: shard mode ------------------------------------------------
    def _dispatch_shard(self, Q, deadline_s):
        pol = self.rpolicy
        nq = Q.shape[0]
        served: list[tuple[ReplicaState, SearchResult, float]] = []
        missing: list[ReplicaState] = []
        total_rows = sum(rs.rows for rs in self.replicas)
        walls: list[float] = []
        for rs in self.replicas:
            if rs.state == "open":
                missing.append(rs)        # ejected: don't waste the budget
                continue
            shard_wall, got = 0.0, None
            for attempt in range(pol.max_retries + 1):
                if attempt > 0:
                    self.retries += 1
                    shard_wall += self._backoff(attempt)
                try:
                    got, w = self._replica_search(rs, Q, deadline_s)
                except Exception as exc:  # noqa: BLE001 — shard retry
                    self._note_failure(rs, exc)
                    shard_wall += getattr(exc, "wall_s", 0.0)
                    if rs.state == "open":
                        break             # ejected mid-retry: stop burning
                    continue
                self._note_success(rs, w)
                shard_wall += w
                break
            walls.append(shard_wall)
            if got is None:
                missing.append(rs)
            else:
                served.append((rs, got, shard_wall))
        # the fan-out runs shards concurrently: the batch wall is the
        # slowest shard's (retries included), not the sum
        wall = max(walls, default=0.0)
        if not served:
            raise ReplicaDispatchError(
                f"all {len(self.replicas)} shards failed or are ejected",
                wall_s=wall)
        return self._merge_shards(served, missing, nq, total_rows), wall

    def _merge_shards(self, served, missing, nq: int, total_rows: int):
        """Merge per-shard top-k into the global top-k: re-base local ids
        by each shard's row offset, concatenate, and keep the k best per
        query.  Coverage/certificates compose shard-wise: a query's
        spatial coverage is the row-weighted mean of its per-shard scan
        coverage over *served* shards (missing shards contribute 0), and
        its certificate survives only if every shard is present and
        certified."""
        from repro.api.types import SearchResult

        k = self.k
        dists = np.concatenate([r.dists for _, r, _ in served], axis=1)
        ids = np.concatenate(
            [r.ids + rs.id_offset for rs, r, _ in served], axis=1)
        # mask padded/invalid lanes (a shard with n < k pads with inf)
        order = np.argsort(dists, axis=1, kind="stable")[:, :k]
        rowi = np.arange(nq)[:, None]
        out_d = dists[rowi, order]
        out_i = ids[rowi, order]
        cov = np.zeros(nq, np.float32)
        unc = np.zeros(nq, bool)
        stats = ScanStats()
        for rs, r, _ in served:
            frac = rs.rows / max(total_rows, 1)
            scov = r.stats.extra.get(EXTRA_COVERAGE)
            cov += np.float32(frac) * (np.ones(nq, np.float32) if scov is None
                                       else np.asarray(scov, np.float32))
            smask = r.stats.extra.get(EXTRA_UNCERTIFIED_MASK)
            if smask is not None:
                unc |= np.asarray(smask, bool)
            stats.dims_scanned += r.stats.dims_scanned
            stats.dims_total += r.stats.dims_total
            stats.n_dco += r.stats.n_dco
            stats.n_true += r.stats.n_true
        degraded = bool(missing)
        if degraded:
            unc |= True                   # an unvisited shard may hold a
        stats.extra = {                   # true neighbor: withdraw all
            EXTRA_UNCERTIFIED_MASK: unc,
            EXTRA_UNCERTIFIED_QUERIES: float(unc.mean()),
            EXTRA_COVERAGE: cov,
            EXTRA_DEGRADED: 1.0 if degraded else 0.0,
            EXTRA_REPLICA: -1.0,
            EXTRA_HEDGED: 0.0,
        }
        return SearchResult(out_d, out_i, stats, 0.0,
                            served[0][1].backend)

    # -- SearchService overrides ---------------------------------------------
    def _dispatch(self, Q, deadline_s):
        self._tick_round()
        if self.mode == "shard":
            return self._dispatch_shard(Q, deadline_s)
        return self._dispatch_replicate(Q, deadline_s)

    def _visible_rows(self) -> int:
        if self.mode == "shard":
            return sum(rs.rows for rs in self.replicas)
        return max(int(rs.session.n) for rs in self.replicas)

    def step(self, *, now: float | None = None):
        out = super().step(now=now)
        for req in out:
            if req.status == "done" and req.stats.get(EXTRA_DEGRADED):
                self.degraded += 1
        return out

    def add(self, Xnew, *, now: float | None = None) -> dict:
        """Write fan-out.  ``replicate``: every replica applies the rows
        (replicas stay identical).  ``shard``: the rows append to the
        *last* shard — the one holding the tail of the global id range —
        so global ids stay contiguous and merge re-basing stays a plain
        offset add."""
        t0 = time.perf_counter()
        if self.mode == "shard":
            targets = [max(self.replicas, key=lambda rs: rs.id_offset)]
        else:
            targets = self.replicas
        for rs in targets:
            rs.session.add(Xnew)
            rs.rows = int(rs.session.n)
        wall = time.perf_counter() - t0
        mode = targets[-1].session.last_write_mode
        rows = int(np.atleast_2d(Xnew).shape[0])
        self.rows_inserted += rows
        self.insert_s += wall
        self.write_modes[mode] = self.write_modes.get(mode, 0) + 1
        return {"rows": rows, "mode": mode, "wall_s": wall}

    def health(self) -> dict:
        """The base snapshot (accounting invariant unchanged) plus the
        tier: per-replica state rows, retry/hedge telemetry, and the
        degraded-completion count (a subset of ``completed``)."""
        h = super().health()
        h["mode"] = self.mode
        h["replicas"] = [rs.report() for rs in self.replicas]
        h["retries"] = self.retries
        h["hedges"] = self.hedges
        h["hedge_wins"] = self.hedge_wins
        h["hedge_losses"] = self.hedge_losses
        h["degraded"] = self.degraded
        return h


def open_replicated(X, *, replicas: int = 3, mode: str = "replicate",
                    index: str = "flat", method: str = "DADE",
                    backend: str | None = None, schedule=None,
                    replica_policy: ReplicaPolicy | None = None,
                    seed: int = 0, **serving_kwargs) -> ReplicatedService:
    """Build a replicated serving tier from one corpus.

    ``mode="replicate"`` fits ``replicas`` identical sessions over the
    full corpus (deterministic fits: same rows, same seed).
    ``mode="shard"`` splits the rows into ``replicas`` contiguous ranges
    and fits one session per range; the tier re-bases ids at merge time,
    so results match a single session over the whole corpus wherever all
    shards are live.  Remaining kwargs go to ``ReplicatedService`` /
    ``SearchService`` (slots, k, max_queue, clock, rng, timer, ...).
    """
    from repro.api.session import open_index

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if mode not in REPLICA_MODES:
        raise ValueError(f"mode must be one of {REPLICA_MODES}, got {mode!r}")
    X = np.ascontiguousarray(np.atleast_2d(X), np.float32)
    if mode == "shard":
        bounds = np.linspace(0, X.shape[0], replicas + 1).astype(int)
        parts = [X[bounds[i]:bounds[i + 1]] for i in range(replicas)]
        if any(p.shape[0] == 0 for p in parts):
            raise ValueError(
                f"cannot cut {X.shape[0]} rows into {replicas} non-empty "
                "shards")
    else:
        parts = [X] * replicas
    sessions = [open_index(p, index=index, method=method, backend=backend,
                           schedule=schedule, seed=seed) for p in parts]
    return ReplicatedService(sessions, mode=mode,
                             replica_policy=replica_policy, **serving_kwargs)
