from repro.train.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.train.train_step import TrainState, make_train_step  # noqa: F401
