"""Checkpointing: async, atomic, elastic.

Layout: <dir>/step_<n>/{manifest.json, <idx>.npy ...}; a checkpoint is
valid iff its ``manifest.json`` exists (written LAST, after every tensor) —
the atomicity marker that makes interrupted saves harmless.

* ``save_async`` snapshots to host memory synchronously (device_get) and
  writes on a daemon thread: the train loop blocks only for the D2H copy.
* ``restore`` loads the newest valid step into ANY target shardings — arrays
  are saved unsharded, so restoring onto a different mesh (elastic
  scale-up/down, pod loss) is just a device_put with the new specs.
* ``GC``: keep_last bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(tree, directory: str, step: int, *, keep_last: int = 3):
    leaves, _ = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    _write(host, directory, step, keep_last)


_PENDING: list = []


def save_async(tree, directory: str, step: int, *, keep_last: int = 3):
    """D2H synchronously, disk write on a background thread."""
    leaves, _ = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    t = threading.Thread(target=_write, args=(host, directory, step, keep_last),
                         daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    while _PENDING:
        _PENDING.pop().join()


def _write(host_leaves, directory, step, keep_last):
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    for i, arr in enumerate(host_leaves):
        np.save(os.path.join(tmp, f"{i}.npy"), arr)
    meta = {"step": step, "n_leaves": len(host_leaves)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # GC old checkpoints
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def latest_steps(directory):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return out


def restore(template, directory: str, *, shardings=None, step: int | None = None):
    """Restore newest (or given) step into ``template``'s structure.
    ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
    this is the elastic-rescale path."""
    steps = latest_steps(directory)
    if not steps:
        return None, -1
    step = max(steps) if step is None else step
    d = os.path.join(directory, f"step_{step:010d}")
    leaves, treedef = _flatten(template)
    host = [np.load(os.path.join(d, f"{i}.npy")) for i in range(len(leaves))]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        host = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        host = [jax.device_put(h.astype(l.dtype) if hasattr(l, 'dtype') else h)
                for h, l in zip(host, leaves)]
    return jax.tree.unflatten(treedef, host), step
