"""AdamW with decoupled weight decay, plain-pytree state.

Moment dtype is configurable: bf16 moments halve optimizer HBM (the knob
that lets deepseek-v3 fit the multi-pod mesh — EXPERIMENTS.md §Dry-run).
Moments shard exactly like their parameters (ZeRO-style for free, since the
params are already 2D-sharded by configs.sharding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, *, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, grad_clip=1.0):
    step = opt["step"] + 1
    # global-norm clip (f32 accumulation over possibly-bf16 grads)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
