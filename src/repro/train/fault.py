"""Fault tolerance & straggler machinery for 1000+ node runs.

On a real multi-host cluster these hooks bind to the coordination service
(heartbeats, preemption notices); this container is single-host, so the same
logic is driven by step timing and signals — and the restart path is
exercised for real by tests/test_fault.py (kill mid-run, resume, bitwise
continuation).

Components:
  * StepMonitor  — per-step EWMA timing; a step slower than ``ratio``x the
    EWMA marks the host as straggling.  At scale the action is to evict the
    replica and rebuild the mesh (elastic), which is exactly what
    ``plan_elastic_remesh`` computes.
  * PreemptionGuard — SIGTERM/SIGINT => finish the current step, synchronous
    checkpoint, exit cleanly (the TPU maintenance-event pattern).
  * run_resumable  — checkpoint/restart training driver: restores the newest
    valid checkpoint (params+opt+step+data cursor), saves async every
    ``ckpt_every`` steps.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax

from repro.train import checkpoint as ckpt


@dataclass
class StepMonitor:
    ratio: float = 2.5
    alpha: float = 0.1
    ewma: float = 0.0
    n: int = 0
    stragglers: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step looked straggly."""
        if self.n >= 3 and dt > self.ratio * self.ewma:
            self.stragglers.append((step, dt, self.ewma))
            slow = True
        else:
            slow = False
        self.ewma = dt if self.n == 0 else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        self.n += 1
        return slow


def plan_elastic_remesh(mesh_shape: tuple, axis_names: tuple, lost: int):
    """Given ``lost`` failed hosts, compute the largest healthy sub-mesh that
    keeps the "model" axis intact (TP groups must stay whole) by shrinking
    the outermost DP axis.  Returns (new_shape, dropped_replicas)."""
    shape = list(mesh_shape)
    tp = shape[-1]
    dp_total = 1
    for s in shape[:-1]:
        dp_total *= s
    # each DP replica spans `tp` chips; losing any chip kills its replica
    lost_replicas = min(dp_total, (lost + tp - 1) // tp)
    new_dp = dp_total - lost_replicas
    if new_dp <= 0:
        raise RuntimeError("no healthy replicas left")
    return (new_dp, tp), lost_replicas


class PreemptionGuard:
    def __init__(self):
        self.requested = False
        self._orig = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._orig[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for sig, h in self._orig.items():
            signal.signal(sig, h)
        return False


def run_resumable(train_step, state_template, data_fn, *, steps: int,
                  ckpt_dir: str, ckpt_every: int = 50, monitor=None,
                  fail_at: int | None = None):
    """Checkpoint/restart driver.  ``data_fn(step)`` must be stateless
    (indexed access) so the data order is reproducible across restarts.
    ``fail_at`` injects a crash (tests).  Returns (state, last_step)."""
    state, start = ckpt.restore(state_template, ckpt_dir)
    if state is None:
        state, start = state_template, -1
    monitor = monitor or StepMonitor()
    with PreemptionGuard() as guard:
        for step in range(start + 1, steps):
            t0 = time.perf_counter()
            state, metrics = train_step(state, data_fn(step))
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            monitor.record(step, time.perf_counter() - t0)
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            if step % ckpt_every == 0 or guard.requested or step == steps - 1:
                ckpt.save_async(state, ckpt_dir, step)
            if guard.requested:
                ckpt.wait_pending()
                return state, step
    ckpt.wait_pending()
    return state, steps - 1
