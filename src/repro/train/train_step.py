"""train_step builder: mixed precision, microbatching, gradient compression.

Distributed-optimization tricks (brief §2):
  * bf16 parameter cast before the backward pass => the FSDP grad
    reduce-scatters/all-reduces move bf16 bytes (2x collective compression),
    while AdamW applies them to f32 master params;
  * microbatch gradient accumulation via lax.scan bounds activation memory
    independently of the global batch;
  * remat policy is owned by the model builder ("block" wraps each scanned
    layer body in jax.checkpoint);
  * compute/comm overlap comes from XLA latency-hiding scheduling of the
    scan-structured FSDP all-gathers (we verify collective placement in the
    dry-run HLO rather than hand-rolling double buffering).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import adamw_init, adamw_update


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: Any

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda aux, kids: TrainState(*kids))


def init_state(api, key, *, moment_dtype=jnp.float32) -> TrainState:
    params = api.init(key)
    return TrainState(params, adamw_init(params, moment_dtype=moment_dtype),
                      jnp.zeros((), jnp.int32))


def lr_schedule(step, *, peak=3e-4, warmup=100, total=10_000):
    warm = peak * (step + 1) / warmup
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def make_train_step(api, *, microbatches: int = 1,
                    grad_dtype=jnp.bfloat16, lr_fn: Callable = lr_schedule,
                    weight_decay: float = 0.1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_over(params_half, batch):
        return api.loss(params_half, batch)

    def train_step(state: TrainState, batch):
        # bf16 forward/backward params; grads land in bf16 => compressed
        # collectives on the FSDP reduce path.
        p_half = jax.tree.map(
            lambda p: p.astype(grad_dtype) if p.dtype == jnp.float32 else p,
            state.params)

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_over, has_aux=True)(p_half, batch)
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            mb = B // microbatches
            batch_m = jax.tree.map(
                lambda x: x.reshape((microbatches, mb) + x.shape[1:]), batch)

            def acc_fn(carry, mbatch):
                (l0, g0) = carry
                (l, m), g = jax.value_and_grad(loss_over, has_aux=True)(
                    p_half, mbatch)
                g = jax.tree.map(jnp.add, g0, g)
                return (l0 + l, g), m

            g_init = jax.tree.map(jnp.zeros_like, p_half)
            (loss, grads), ms = jax.lax.scan(acc_fn, (0.0, g_init), batch_m)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda x: x[-1], ms)

        lr = lr_fn(state.step)
        new_params, new_opt, gnorm = adamw_update(
            state.params, grads, state.opt, lr=lr, weight_decay=weight_decay)
        metrics = dict(metrics, loss=loss, gnorm=gnorm, lr=lr)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
