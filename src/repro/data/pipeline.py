"""Deterministic, resumable token data pipeline.

Batches are a pure function of (seed, step) — counter-based generation via
threefry — so a restarted job consumes the identical stream with no cursor
file (the brief's deterministic-resume requirement).  A host-side prefetch
thread keeps ``depth`` batches in flight ahead of the train loop.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


def make_batch_fn(cfg, shape, *, seed: int = 0):
    """Returns batch_fn(step) -> batch dict for the arch family; stateless."""
    B, S = shape.global_batch, shape.seq_len

    def batch_fn(step: int):
        rng = np.random.default_rng((seed * 1_000_003 + step) % (2 ** 63))
        out = {"tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
        if cfg.family == "encdec":
            out["src_embeds"] = rng.standard_normal(
                (B, min(S, 1024), cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm" and cfg.prefix_len:
            out["patches"] = rng.standard_normal(
                (B, cfg.prefix_len, cfg.d_model)).astype(np.float32)
        return out

    return batch_fn


class TokenPipeline:
    """Prefetching wrapper: ``for step, batch in pipeline.iter(start, stop)``."""

    def __init__(self, batch_fn, *, depth: int = 2):
        self.batch_fn = batch_fn
        self.depth = depth

    def iter(self, start: int, stop: int):
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop_flag = threading.Event()

        def producer():
            for step in range(start, stop):
                if stop_flag.is_set():
                    return
                q.put((step, self.batch_fn(step)))
            q.put(None)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                yield item
        finally:
            stop_flag.set()
