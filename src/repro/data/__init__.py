from repro.data.pipeline import TokenPipeline, make_batch_fn  # noqa: F401
