from repro.utils.timing import Timer, bench_call  # noqa: F401
