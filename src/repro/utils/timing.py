"""Tiny wall-clock measurement helpers shared by benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating named timer: ``with timer('phase'): ...``."""

    totals: dict = field(default_factory=dict)
    counts: dict = field(default_factory=dict)

    @contextmanager
    def __call__(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def mean_us(self, name: str) -> float:
        return 1e6 * self.totals.get(name, 0.0) / max(1, self.counts.get(name, 0))


def bench_call(fn, *args, warmup: int = 2, iters: int = 5, **kwargs):
    """Return (mean_seconds, last_result) for ``fn(*args, **kwargs)``."""
    result = None
    for _ in range(warmup):
        result = fn(*args, **kwargs)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kwargs)
    return (time.perf_counter() - t0) / iters, result
