"""Backend executors behind ``SearchSession``.

``HostBackend`` runs the staged numpy scan (core.engine.scan_topk) over a
flat corpus, an IVF partition probe, or an HNSW graph walk.  ``JaxBackend``
runs the batched two-stage device engine (core.jax_engine) over a flat
corpus — single device or, when a mesh is supplied, sharded with a global
top-k merge.  Both consume the SAME fitted method state: the host path via
``method.screen``/``exact_sq``, the device path via the method's uniform
``device_state()`` export.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import QueryBatch, ScanStats, scan_topk


class HostBackend:
    """Numpy staged-scan execution over flat / IVF / HNSW candidates."""

    name = "host"

    def __init__(self, method, index_kind: str, index, policy):
        self.method = method
        self.index_kind = index_kind
        self.index = index
        self.policy = policy

    def invalidate(self):           # nothing cached on the host path
        pass

    def search(self, Q, k: int, *, nprobe: int, ef: int):
        m = self.method
        batch = QueryBatch.create(m, Q, self.policy.stage_dims(m.state["D"]))
        dists = np.empty((len(batch), k), np.float32)
        ids = np.empty((len(batch), k), np.int64)
        all_ids = None
        for qi in range(len(batch)):
            if self.index_kind == "flat":
                if all_ids is None:
                    all_ids = np.arange(m.state["N"])
                d, i = scan_topk(m, batch, qi, all_ids, k)
            elif self.index_kind == "ivf":
                d, i = self.index.search(m, batch, qi, k, nprobe)
            else:                   # hnsw
                d, i = self.index.search(m, batch, qi, k, max(ef, k))
            n = min(k, len(d))
            dists[qi, :n], ids[qi, :n] = d[:n], i[:n]
            if n < k:
                dists[qi, n:], ids[qi, n:] = np.inf, -1
        return dists, ids, batch.stats


class JaxBackend:
    """Two-stage device engine over a flat corpus (optionally mesh-sharded).

    Lazily materializes the dimension-blocked device arrays from
    ``method.device_state()`` and rebuilds them after ``invalidate()`` (the
    session calls it on ``add``).  Query padding to the chunk size is handled
    inside ``two_stage_topk``, so ragged batches are fine.
    """

    name = "jax"

    def __init__(self, method, index_kind: str, index, policy, *, mesh=None):
        if index_kind != "flat":
            raise ValueError(
                f"backend='jax' serves index='flat' (got {index_kind!r}); "
                "IVF probes and HNSW graph walks are host-side indexes")
        self.method = method
        self.policy = policy
        self.mesh = mesh
        self._dstate = None         # host-side device_state() export
        self._state = None          # jnp arrays (single-device path)
        self._shard_args = None     # device_put shards (mesh path)
        self._mesh_fns: dict = {}   # cfg -> shard_map fn

    # -- state management ---------------------------------------------------
    def invalidate(self):
        self._dstate = self._state = self._shard_args = None
        self._mesh_fns.clear()

    def _materialize(self):
        from repro.core.jax_engine import build_device_state, rule_scalars

        dstate = self.method.device_state()
        xr = np.asarray(dstate["Xrot"], np.float32)
        D = self.method.state["D"]
        if xr.shape[1] != D:
            raise ValueError(
                f"{self.method.name}: rotation rank {xr.shape[1]} < D={D}; "
                "the device engine needs a full-rank rotation for exact "
                "stage-2 completion — use backend='host' at this D")
        self._dstate = dstate
        self._d1 = min(self.policy.d1, D)
        if self.mesh is None:
            self._state = build_device_state(dstate, self._d1)
        else:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P

            sh = NamedSharding(self.mesh, P(tuple(self.mesh.axis_names)))
            d1 = self._d1
            self._shard_args = tuple(
                jax.device_put(v, sh)
                for v in (xr[:, :d1], xr[:, d1:],
                          (xr[:, :d1] ** 2).sum(1), (xr[:, d1:] ** 2).sum(1)))
            self._mesh_extra_state = rule_scalars(dstate, d1)

    def _config(self, k: int):
        from repro.core.jax_engine import DcoEngineConfig

        ds, p = self._dstate, self.policy
        kw = dict(kind=ds["kind"], d1=self._d1, k=k, capacity=p.capacity,
                  query_chunk=p.query_chunk, tau_slack=p.tau_slack)
        if ds["kind"] == "adsampling":
            kw["eps0"] = float(ds.get("eps0", 2.1))
        elif ds["kind"] == "ddcres":
            kw["m"] = float(ds.get("m", 3.0))
        elif ds["kind"] == "ratio":
            kw["theta"] = self._ratio_theta(k)
        return DcoEngineConfig(**kw)

    def _ratio_theta(self, k: int) -> float:
        """Largest trained stage <= d1 for the trained k; theta=1.0 (exact
        lower-bound rule) when no model applies."""
        models = self._dstate.get("models") or {}
        trained = [(d, th) for (kk, d), th in models.items()
                   if kk == self._dstate.get("trained_k") and d <= self._d1]
        return max(trained)[1] if trained else 1.0

    def _prep_queries(self, Q):
        """Rotate/center queries into the device basis + DDCres per-query
        scalars (tail query energy and Eq. 6 variance suffix at d1)."""
        ds, d1 = self._dstate, self._d1
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        Qp = Q - ds["mean"] if ds.get("mean") is not None else Q
        Qr = Qp @ ds["W"] if ds.get("W") is not None else Qp
        q_extra = {}
        if ds["kind"] == "ddcres":
            qres = np.clip((Qp ** 2).sum(1) - (Qr ** 2).sum(1), 0.0, None)
            var = ((Qr[:, d1:] ** 2) * ds["sigma_sq"][None, d1:]).sum(1)
            q_extra = {
                "qtail_sq": (Qr[:, d1:] ** 2).sum(1) + qres,
                "var_d1": var + qres * float(ds["tail_var"]),
            }
        return Qr[:, :d1], Qr[:, d1:], q_extra

    # -- search --------------------------------------------------------------
    def search(self, Q, k: int, *, nprobe: int, ef: int):
        import jax
        import jax.numpy as jnp
        from repro.core.jax_engine import make_distributed_topk, two_stage_topk

        if self._dstate is None:
            self._materialize()
        cfg = self._config(k)
        ql, qt, qe = self._prep_queries(Q)
        nq, N, D = ql.shape[0], self.method.state["N"], self.method.state["D"]
        stats = ScanStats(n_dco=nq * N, dims_total=float(nq) * N * D)
        if self.mesh is None:
            d, i, surv = two_stage_topk(
                self._state, jnp.asarray(ql), jnp.asarray(qt), cfg,
                {key: jnp.asarray(v) for key, v in qe.items()})
            surv = np.asarray(surv)
        else:
            if cfg not in self._mesh_fns:
                self._mesh_fns[cfg] = jax.jit(
                    make_distributed_topk(self.mesh, cfg,
                                          tuple(self.mesh.axis_names),
                                          extra_state=self._mesh_extra_state))
            d, i = self._mesh_fns[cfg](*self._shard_args,
                                       jnp.asarray(ql), jnp.asarray(qt),
                                       {key: jnp.asarray(v)
                                        for key, v in qe.items()})
            surv = np.full(nq, min(cfg.capacity, N))    # per-shard upper bound
        jax.block_until_ready(d)
        if cfg.kind == "fdscan":
            stats.dims_scanned = stats.dims_total
        else:
            # stage 1 streams d1 dims for every row; stage 2 + the k anchor
            # completions stream the tail for survivors only
            stats.dims_scanned = (float(nq) * N * self._d1
                                  + float(surv.sum() + nq * k) * (D - self._d1))
            stats.extra["survivors_mean"] = float(surv.mean())
        return (np.asarray(d, np.float32), np.asarray(i, np.int64), stats)


def make_backend(name: str, method, index_kind: str, index, policy, *, mesh=None):
    if name == "host":
        if mesh is not None:
            raise ValueError("mesh sharding is a jax-backend feature")
        return HostBackend(method, index_kind, index, policy)
    if name == "jax":
        return JaxBackend(method, index_kind, index, policy, mesh=mesh)
    raise ValueError(f"unknown backend {name!r} (expected 'host' or 'jax')")
