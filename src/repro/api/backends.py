"""Backend executors behind ``SearchSession``.

``HostBackend`` runs the staged numpy scan (core.engine.scan_topk) over a
flat corpus, an IVF partition probe, or an HNSW graph walk.  ``JaxBackend``
runs the device engines over a flat corpus — the streaming block-fused scan
(core.stream_engine, default) or the legacy two-stage engine
(core.jax_engine) — single device or, when a mesh is supplied, sharded with
a global top-k merge.  A flat corpus can also be probed IVF-style on device:
rows are laid out partition-major and the streaming engine masks/skips
unprobed partitions.  Both backends consume the SAME fitted method state:
the host path via ``method.screen``/``exact_sq``, the device path via the
method's uniform ``device_state()`` export.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.engine import (EXTRA_COVERAGE, EXTRA_DIMS_READ_MEAN,
                               EXTRA_EST_SAVED_FLOPS, EXTRA_FALLBACK_BLOCKS,
                               EXTRA_RULE_TIMELINE, EXTRA_SCREEN_PASS_MEAN,
                               EXTRA_SURVIVORS_MEAN, EXTRA_UNCERTIFIED_MASK,
                               EXTRA_UNCERTIFIED_QUERIES, QueryBatch,
                               ScanStats, scan_topk)
from repro.core.policy import PolicyConfig, finalize_adaptive_extra
from repro.testing import faults


def _arm_guardrail(method, index_kind: str, policy, backend: str):
    """Build the per-(method, backend) breaker when the schedule asks for
    one (DESIGN.md §9).  HNSW walks have no scan-shaped certified fallback
    to demote to (rejected); ``FDScanning`` already IS the certified full
    scan, so there is nothing to guard (silently unarmed — documented in
    docs/methods.md)."""
    gcfg = getattr(policy, "guardrails", None)
    if gcfg is None or gcfg is False:
        return None
    if index_kind == "hnsw":
        raise ValueError(
            "guardrails demote scan-shaped searches (index='flat'/'ivf') to "
            "a certified full scan; an HNSW graph walk has no such fallback "
            "(DESIGN.md §9)")
    if method.name == "FDScanning":
        return None
    from repro.core.guardrails import Guardrail, GuardrailConfig
    if gcfg is True:
        gcfg = GuardrailConfig()
    return Guardrail(gcfg, method, backend)


class HostBackend:
    """Numpy staged-scan execution over flat / IVF / HNSW candidates."""

    name = "host"

    def __init__(self, method, index_kind: str, index, policy):
        self.method = method
        self.index_kind = index_kind
        self.index = index
        self.policy = policy
        # adaptive fdscan fallback (DESIGN.md §5) for the scan-shaped index
        # kinds; HNSW graph walks screen tiny per-hop batches with a
        # different cost structure and ignore it
        self._pol = PolicyConfig.from_schedule(policy)
        # demoted serving: every candidate block completes exactly
        self._pol_demoted = PolicyConfig(adaptive=True, force_fallback=True)
        self.guardrail = _arm_guardrail(method, index_kind, policy, "host")

    def invalidate(self):
        """No-op: nothing is cached on the host path."""
        pass

    def notify_append(self, n_new: int, parts=None) -> str:
        """Inserts need no layout work on the host path (the scan reads the
        method's live numpy arrays); returns the write mode for telemetry
        parity with the jax backend."""
        return "noop"

    def search(self, Q, k: int, *, nprobe: int, ef: int,
               deadline_s: float | None = None):
        """Batched staged-scan top-k; returns (dists, ids, stats).

        ``deadline_s`` (seconds of wall budget for the whole batch) arms
        anytime mode (DESIGN.md §7): the scan checks the clock between
        candidate blocks, queries past the budget return their running
        top-k, and per-query ``coverage`` (candidate blocks scanned, 1.0 =
        complete) lands in ``stats.extra`` with partial queries flagged in
        ``uncertified_mask``.

        With ``SchedulePolicy(guardrails=...)`` armed, non-deadline batches
        route through the breaker (DESIGN.md §9): drift is scored, a
        sampled audit shadow-runs the certified path, and an OPEN breaker
        serves the whole batch by the exhaustive certified scan.  Deadline
        calls bypass the guardrail (anytime partials are already flagged
        uncertified and must stay deterministic)."""
        faults.check_search(faults.active(self.policy))
        g = self.guardrail
        if g is not None and deadline_s is None:
            return g.run(
                Q, k,
                screen=lambda q: self._search(q, k, nprobe=nprobe, ef=ef),
                certified=lambda q: self._search(q, k, nprobe=nprobe, ef=ef,
                                                 demoted=True),
                plan=faults.active(self.policy))
        return self._search(Q, k, nprobe=nprobe, ef=ef,
                            deadline_s=deadline_s)

    def _search(self, Q, k: int, *, nprobe: int, ef: int,
                deadline_s: float | None = None, demoted: bool = False):
        """The scan itself; ``demoted=True`` serves every candidate block
        by the exhaustive exact completion (``PolicyConfig(force_fallback)``
        pins the host policy's fallback mode — the guardrail's certified
        reference/serving path)."""
        m = self.method
        t_end = None
        if deadline_s is not None:
            if self.index_kind == "hnsw":
                raise ValueError(
                    "anytime deadlines interrupt scan-shaped searches "
                    "(index='flat'/'ivf'); an HNSW graph walk has no block "
                    "boundary to stop at (DESIGN.md §7)")
            t_end = time.monotonic() + float(deadline_s)
        pol = self._pol_demoted if demoted else self._pol
        batch = QueryBatch.create(m, Q, self.policy.stage_dims(m.state["D"]))
        dists = np.empty((len(batch), k), np.float32)
        ids = np.empty((len(batch), k), np.int64)
        all_ids = None
        for qi in range(len(batch)):
            if self.index_kind == "flat":
                if all_ids is None:
                    all_ids = np.arange(m.state["N"])
                d, i = scan_topk(m, batch, qi, all_ids, k, policy=pol,
                                 deadline_ts=t_end)
            elif self.index_kind == "ivf":
                d, i = self.index.search(m, batch, qi, k, nprobe,
                                         policy=pol, deadline_ts=t_end)
            else:                   # hnsw
                d, i = self.index.search(m, batch, qi, k, max(ef, k))
            n = min(k, len(d))
            dists[qi, :n], ids[qi, :n] = d[:n], i[:n]
            if n < k:
                dists[qi, n:], ids[qi, n:] = np.inf, -1
        self._finalize_stats(batch.stats, len(batch))
        return dists, ids, batch.stats

    @staticmethod
    def _finalize_stats(stats, nq: int) -> None:
        """Fold scan accumulators into the canonical ``extra`` telemetry
        keys (api.types.STAT_EXTRA_KEYS) so host batches report the same
        fields as the jax backend."""
        completed = stats.extra.pop("_completed_total", None)
        if completed is not None:
            # no completion budget on the host scan: pass == completed
            stats.extra[EXTRA_SURVIVORS_MEAN] = completed / max(nq, 1)
            stats.extra[EXTRA_SCREEN_PASS_MEAN] = completed / max(nq, 1)
        # every host survivor is exactly completed -> certified, UNLESS an
        # anytime deadline cut the scan short: unscanned candidate blocks
        # may hold true neighbors, so partial queries are uncertified
        cov = stats.extra.pop("_coverage", None)
        coverage = np.ones(nq, np.float32)
        if cov is not None:
            coverage[:len(cov)] = np.asarray(cov, np.float32)
        stats.extra[EXTRA_COVERAGE] = coverage
        stats.extra[EXTRA_UNCERTIFIED_MASK] = coverage < 1.0
        stats.extra[EXTRA_UNCERTIFIED_QUERIES] = float(
            (coverage < 1.0).mean())
        stats.extra[EXTRA_DIMS_READ_MEAN] = (
            stats.dims_scanned / max(stats.n_dco, 1))
        finalize_adaptive_extra(stats)


class JaxBackend:
    """Device engines over a flat or IVF-probed corpus (flat optionally
    mesh-sharded).

    Lazily materializes the dimension-blocked device arrays from
    ``method.device_state()`` and rebuilds them after ``invalidate()``.
    Dynamic inserts take the LSM-style write path (DESIGN.md §6): the
    session's ``add`` calls ``notify_append``, which keeps the cached main
    block layout and serves the new rows from a small delta segment scanned
    alongside it (one running tau across both segments), re-materializing
    only once the delta exceeds ``SchedulePolicy.delta_merge_threshold``
    rows.  Query padding to the chunk size is handled inside the engines, so
    ragged batches are fine.
    """

    name = "jax"

    def __init__(self, method, index_kind: str, index, policy, *, mesh=None):
        if index_kind not in ("flat", "ivf"):
            raise ValueError(
                f"backend='jax' serves index='flat' or 'ivf' (got "
                f"{index_kind!r}); HNSW graph walks are host-side indexes")
        if index_kind == "ivf" and mesh is not None:
            raise ValueError(
                "device IVF probing is single-device; mesh-shard a flat "
                "corpus instead")
        if mesh is not None and getattr(policy, "adaptive", False):
            raise ValueError(
                "the adaptive DCO policy is single-device for now — drop "
                "SchedulePolicy(adaptive=True) on the mesh path "
                "(DESIGN.md §5)")
        if mesh is not None and getattr(policy, "guardrails", None) is not None:
            raise ValueError(
                "guardrails are single-device (the breaker's demotion runs "
                "the streaming engine's forced full-scan body) — drop "
                "SchedulePolicy(guardrails=...) on the mesh path "
                "(DESIGN.md §9)")
        self.method = method
        self.index_kind = index_kind
        self.index = index
        self.policy = policy
        self.mesh = mesh
        self._dstate = None         # host-side device_state() export
        self._state = None          # jnp arrays (single-device path)
        self._blocks = None         # cached stream-engine corpus layout
        self._groups = 1            # resolved PDX dim groups of that layout
        self._shard_args = None     # device_put shards (mesh path)
        self._mesh_fns: dict = {}   # cfg -> shard_map fn
        self._mesh_row_block = None  # shard-aligned row_block (mesh path)
        self._list_sizes = None     # IVF partition sizes (probe stats)
        self._cfg_cache: dict = {}  # (k, anytime, demoted) -> DcoEngineConfig
                                    # (same object per call so jit static-arg
                                    # caching stays on the identity fast path)
        self.guardrail = _arm_guardrail(method, index_kind, policy, "jax")
        # ---- LSM-style delta segment (DESIGN.md §6) ----
        self._n_main = 0            # rows in the materialized main layout
        self._delta_parts = np.empty(0, np.int32)   # IVF parts of delta rows
        self._delta_blocks = None   # cached combined main+delta layout
        self._delta_tail_min = np.inf
        self._delta_dirty = False
        # write-path telemetry (bench_serving's insert amplification)
        self.rows_inserted = 0      # rows arriving through notify_append
        self.rows_written = 0       # rows laid out on device (full + delta)
        self.merges = 0             # threshold-triggered re-materializations

    # -- state management ---------------------------------------------------
    def invalidate(self):
        """Drop materialized device arrays (full re-materialization on the
        next search; ``notify_append`` is the cheaper delta path for adds)."""
        self._dstate = self._state = self._blocks = self._shard_args = None
        self._groups = 1
        self._list_sizes = None
        self._mesh_fns.clear()
        self._cfg_cache.clear()
        self._n_main = 0
        self._delta_parts = np.empty(0, np.int32)
        self._delta_blocks = None
        self._delta_tail_min = np.inf
        self._delta_dirty = False

    def _resolved_engine(self) -> str:
        """The engine ``search`` will actually run (opq / IVF probing / the
        adaptive policy / guardrail demotion are stream-only); requires a
        materialized _dstate."""
        if (self._dstate["kind"] == "opq" or self.index_kind == "ivf"
                or PolicyConfig.from_schedule(self.policy) is not None
                or self.guardrail is not None):
            return "stream"
        return self.policy.engine

    @property
    def delta_rows(self) -> int:
        """Rows currently served from the delta segment (0 when merged)."""
        if self._dstate is None:
            return 0
        return int(self.method.state["N"]) - self._n_main

    def notify_append(self, n_new: int, parts=None) -> str:
        """Register ``n_new`` rows just appended to the method state.

        Returns the write mode taken:
          ``"delta"``    rows join the delta segment; the cached main block
                         layout survives and the next search scans both
                         segments under one running tau;
          ``"merge"``    the delta exceeded ``delta_merge_threshold`` — the
                         whole layout re-materializes on the next search;
          ``"rebuild"``  delta path unavailable (mesh / two_stage engine /
                         threshold 0): legacy full invalidation;
          ``"cold"``     nothing was materialized yet, so the first search
                         lays out everything at once anyway.
        ``parts`` is the IVF partition assignment of the new rows (required
        for index_kind='ivf'; IVFIndex.insert returns it)."""
        self.rows_inserted += int(n_new)
        if self._dstate is None:
            self.invalidate()
            return "cold"
        thresh = self.policy.delta_merge_threshold
        if self.mesh is not None or thresh <= 0 \
                or self._resolved_engine() != "stream":
            self.invalidate()
            return "rebuild"
        if self.index_kind == "ivf":
            if parts is None:
                raise ValueError("notify_append(index='ivf') needs the "
                                 "partition assignment of the new rows")
            self._delta_parts = np.concatenate(
                [self._delta_parts, np.asarray(parts, np.int32)])
        if self.delta_rows > thresh:
            self.merges += 1
            self.invalidate()
            return "merge"
        self._delta_dirty = True
        return "delta"

    def _build_delta(self):
        """(Re)build the delta segment's blocks at the main layout's width
        and concatenate them after the cached main blocks — the LSM write
        path.  Host work is O(delta) (no transform recompute: methods keep
        Xrot incrementally); the device-side concat copies the main blocks
        (O(N) bandwidth) but never retraces or re-materializes them."""
        import jax.numpy as jnp
        from repro.core.stream_engine import append_stream_blocks

        n_total = int(self.method.state["N"])
        n_delta = n_total - self._n_main
        ds = self.method.device_state()
        if ds["kind"] != self._dstate["kind"]:
            # the method was re-trained under us (kind flip, e.g. DDCopq
            # lb->opq): the cached main layout is for the wrong rule
            self.invalidate()
            self._materialize()
            return self._blocks
        xr = np.asarray(ds["Xrot"], np.float32)[self._n_main:]
        d1 = self._d1
        # quantize the segment to whole blocks HOST-side (same pad rows the
        # device build would add: zeros with id -1) so every delta size
        # within the same block count shares one build/scan trace — without
        # this, each insert changes the input shapes and retraces the jitted
        # build, turning the first post-insert search into a compile stall
        B = int(self._blocks["xl"].shape[-2])
        pad = -n_delta % B
        self._delta_tail_min = float((xr[:, d1:] ** 2).sum(1).min())
        row_ids = np.arange(self._n_main, n_total, dtype=np.int32)
        parts = np.asarray(self._delta_parts, np.int32)
        codes = (np.asarray(ds["codes"], np.int32)[self._n_main:]
                 if ds["kind"] == "opq" else None)
        if pad:
            xr = np.concatenate([xr, np.zeros((pad, xr.shape[1]),
                                              np.float32)])
            row_ids = np.concatenate([row_ids, np.full(pad, -1, np.int32)])
            if parts.size:      # edge-mode, as build_stream_blocks pads
                parts = np.concatenate([parts, np.full(pad, parts[-1],
                                                       np.int32)])
            if codes is not None:
                codes = np.concatenate(
                    [codes, np.zeros((pad, codes.shape[1]), np.int32)])
        dstate = {
            "x_lead": xr[:, :d1], "x_tail": xr[:, d1:],
            "lead_sq": (xr[:, :d1] ** 2).sum(1),
            "tail_sq": (xr[:, d1:] ** 2).sum(1),
            "row_ids": jnp.asarray(row_ids),
        }
        if self.index_kind == "ivf":
            dstate["row_part"] = jnp.asarray(parts)
        if codes is not None:
            dstate["codes"] = jnp.asarray(codes)
        self._delta_blocks = append_stream_blocks(self._blocks, dstate)
        self._delta_dirty = False
        self.rows_written += n_delta
        return self._delta_blocks

    def _materialize(self):
        import jax.numpy as jnp
        from repro.core.jax_engine import build_device_state, rule_scalars

        dstate = self.method.device_state()
        if self.mesh is not None and dstate["kind"] == "opq":
            # PQ screening is single-device for now; mesh shards fall back to
            # the exact lower-bound rule of the base export (same fallback
            # untrained DDCopq uses)
            from repro.core.methods import DCOMethod
            dstate = DCOMethod.device_state(self.method)
        xr = np.asarray(dstate["Xrot"], np.float32)
        D = self.method.state["D"]
        if xr.shape[1] != D:
            raise ValueError(
                f"{self.method.name}: rotation rank {xr.shape[1]} < D={D}; "
                "the device engine needs a full-rank rotation for exact "
                "stage-2 completion — use backend='host' at this D")
        extra = {}
        if self.index_kind == "ivf":
            # partition-major layout: the streaming engine probes by masking
            # row blocks whose partition span was not selected
            part = np.empty(self.method.state["N"], np.int64)
            for j, lst in enumerate(self.index.lists):
                part[lst] = j
            perm = np.argsort(part, kind="stable")
            xr = xr[perm]
            dstate = dict(dstate, Xrot=xr)
            extra["row_ids"] = jnp.asarray(perm, jnp.int32)
            extra["row_part"] = jnp.asarray(part[perm], jnp.int32)
            self._list_sizes = np.array([len(lst) for lst in self.index.lists])
        if dstate["kind"] == "opq":
            codes = np.asarray(dstate["codes"])
            if self.index_kind == "ivf":
                codes = codes[perm]
            extra["codes"] = jnp.asarray(codes, jnp.int32)
        self._dstate = dstate
        self._d1 = min(self.policy.d1, D)
        # PDX vertical layout (DESIGN.md §8): resolve the dim-group count the
        # streaming scan will run with, so the cached blocks, the engine
        # config and the delta segment all share ONE layout.  Forced to 1 off
        # the stream engine and for rules with no partial-distance screen
        # (the same cases stream_engine._effective_groups collapses).
        self._groups = 1
        if (self.mesh is None and self._resolved_engine() == "stream"
                and dstate["kind"] not in ("fdscan", "opq")):
            self._groups = max(1, int(self.policy.dim_groups))
        self._n_main = int(self.method.state["N"])
        self.rows_written += self._n_main
        if self.mesh is None:
            self._state = build_device_state(dstate, self._d1)
            self._state.update(extra)
        else:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P

            sh = NamedSharding(self.mesh, P(tuple(self.mesh.axis_names)))
            d1 = self._d1
            self._shard_args = tuple(
                jax.device_put(v, sh)
                for v in (xr[:, :d1], xr[:, d1:],
                          (xr[:, :d1] ** 2).sum(1), (xr[:, d1:] ** 2).sum(1)))
            self._mesh_extra_state = rule_scalars(dstate, d1)
            # certificate sharp edge (make_distributed_topk): a shard whose
            # row count is not a row_block multiple pads phantom rows inside
            # the compiled call, weakening the per-shard certificate — so
            # align row_block to the largest divisor of the shard size
            # (facade sessions never hit the build-time error)
            from repro.core.jax_engine import _aligned_row_block
            n_shards = int(np.prod(tuple(self.mesh.shape.values())))
            per_shard = max(1, self._n_main // max(n_shards, 1))
            self._mesh_row_block = _aligned_row_block(
                per_shard, self.policy.row_block)

    def _config(self, k: int, anytime: bool = False, demoted: bool = False):
        from repro.core.jax_engine import DcoEngineConfig

        if (k, anytime, demoted) in self._cfg_cache:
            return self._cfg_cache[(k, anytime, demoted)]
        ds, p = self._dstate, self.policy
        row_block = p.row_block if self.mesh is None \
            else getattr(self, "_mesh_row_block", p.row_block)
        kw = dict(kind=ds["kind"], d1=self._d1, k=k, capacity=p.capacity,
                  query_chunk=p.query_chunk, tau_slack=p.tau_slack,
                  row_block=row_block, block_capacity=p.block_capacity,
                  use_kernel=p.use_kernel, dim_groups=self._groups,
                  group_capacity=p.group_capacity)
        if ds["kind"] == "adsampling":
            kw["eps0"] = float(ds.get("eps0", 2.1))
        elif ds["kind"] == "ddcres":
            kw["m"] = float(ds.get("m", 3.0))
        elif ds["kind"] == "ratio":
            kw["theta"] = self._ratio_theta(k)
        elif ds["kind"] == "opq":
            kw["theta"] = float(ds["theta"])
        # fdscan has nothing to fall back to; anytime deadline calls run the
        # fixed resumable scan (DESIGN.md §7), so they strip the policy too.
        # A demoted config (guardrail breaker OPEN / audit reference,
        # DESIGN.md §9) pins force_fallback: every chunk runs the certified
        # full-scan body regardless of what the schedule says.
        if demoted:
            kw["policy"] = PolicyConfig(adaptive=True, force_fallback=True,
                                        fallback_margin=p.fallback_margin)
        elif ds["kind"] != "fdscan" and not anytime:
            kw["policy"] = PolicyConfig.from_schedule(p)
        # resolve use_kernel HERE so the cached config is final: an
        # unresolved None makes stream_topk dataclasses.replace() a fresh
        # static arg every call, pushing jit dispatch onto the slow path
        if kw.get("policy") is not None and ds["kind"] != "opq":
            kw["use_kernel"] = False    # see stream_topk: adaptive forces
                                        # the jnp dco_scan path (pq_lookup
                                        # keeps its kernel)
        elif kw["use_kernel"] is None:
            from repro.kernels.ops import _on_tpu
            kw["use_kernel"] = _on_tpu()
        cfg = DcoEngineConfig(**kw)
        self._cfg_cache[(k, anytime, demoted)] = cfg
        return cfg

    def _ratio_theta(self, k: int) -> float:
        """Largest trained stage <= d1 for the trained k; theta=1.0 (exact
        lower-bound rule) when no model applies."""
        models = self._dstate.get("models") or {}
        trained = [(d, th) for (kk, d), th in models.items()
                   if kk == self._dstate.get("trained_k") and d <= self._d1]
        return max(trained)[1] if trained else 1.0

    def _prep_queries(self, Q):
        """Rotate/center queries into the device basis + per-query extras:
        DDCres scalars (tail query energy and Eq. 6 variance suffix at d1)
        or the DDCopq PQ lookup tables."""
        ds, d1 = self._dstate, self._d1
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        Qp = Q - ds["mean"] if ds.get("mean") is not None else Q
        Qr = Qp @ ds["W"] if ds.get("W") is not None else Qp
        q_extra = {}
        if ds["kind"] == "ddcres":
            qres = np.clip((Qp ** 2).sum(1) - (Qr ** 2).sum(1), 0.0, None)
            var = ((Qr[:, d1:] ** 2) * ds["sigma_sq"][None, d1:]).sum(1)
            q_extra = {
                "qtail_sq": (Qr[:, d1:] ** 2).sum(1) + qres,
                "var_d1": var + qres * float(ds["tail_var"]),
            }
        elif ds["kind"] == "opq":
            from repro.core import transforms as T
            pq = {"books": ds["books"], "splits": ds["splits"]}
            q_extra = {"lut": np.stack([T.pq_query_lut(pq, q) for q in Qr])}
        return Qr[:, :d1], Qr[:, d1:], q_extra

    def _probe(self, Q, nprobe: int):
        """Rank partitions by centroid distance (same rule as the host
        IVFIndex.probe_ids) -> (nq, nprobe) partition ids + candidate counts."""
        cent = self.index.centroids
        npb = min(nprobe, cent.shape[0])
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        d2 = (cent ** 2).sum(1)[None, :] - 2.0 * Q @ cent.T   # +||q||^2 const
        probed = np.argpartition(d2, npb - 1, axis=1)[:, :npb]
        return probed.astype(np.int32), self._list_sizes[probed].sum(1)

    # -- search --------------------------------------------------------------
    def search(self, Q, k: int, *, nprobe: int, ef: int,
               deadline_s: float | None = None):
        """Batched device top-k; returns (dists, ids, stats).  ``ef`` is
        accepted for signature parity with the host backend (unused).

        ``deadline_s`` (seconds of wall budget for the whole batch) arms the
        streaming engine's anytime mode (DESIGN.md §7): the corpus is walked
        in block groups with a wall check at each boundary, an expired
        budget returns the running top-k, and the scanned fraction lands in
        ``stats.extra["coverage"]`` with partial queries flagged
        uncertified.  Single-device stream engine only (the adaptive policy
        is stripped for the deadline call; mesh raises).

        With ``SchedulePolicy(guardrails=...)`` armed, non-deadline batches
        route through the breaker (DESIGN.md §9): drift is scored, a
        sampled audit shadow-runs the certified forced full scan, and an
        OPEN breaker serves the whole batch through it.  Deadline calls
        bypass the guardrail (anytime partials are already flagged
        uncertified and must stay deterministic)."""
        faults.check_search(faults.active(self.policy))
        g = self.guardrail
        if g is not None and deadline_s is None:
            return g.run(
                Q, k,
                screen=lambda q: self._search(q, k, nprobe=nprobe, ef=ef),
                certified=lambda q: self._search(q, k, nprobe=nprobe, ef=ef,
                                                 demoted=True),
                plan=faults.active(self.policy))
        return self._search(Q, k, nprobe=nprobe, ef=ef,
                            deadline_s=deadline_s)

    def _search(self, Q, k: int, *, nprobe: int, ef: int,
                deadline_s: float | None = None, demoted: bool = False):
        """The engine dispatch itself; ``demoted=True`` swaps in the
        forced-fallback config (every chunk runs the certified full-scan
        body — the guardrail's reference/serving path, DESIGN.md §9)."""
        import jax
        import jax.numpy as jnp
        from repro.core.jax_engine import make_distributed_topk, two_stage_topk
        from repro.core.stream_engine import stream_topk

        if self._dstate is None:
            self._materialize()
        t_end = None
        if deadline_s is not None:
            if self.mesh is not None:
                raise ValueError(
                    "anytime deadlines are single-device (the mesh scan has "
                    "no per-group host sync to check the clock at; "
                    "DESIGN.md §7)")
            t_end = time.monotonic() + float(deadline_s)
        cfg = self._config(k, anytime=t_end is not None, demoted=demoted)
        ql, qt, qe = self._prep_queries(Q)
        nq, N, D = ql.shape[0], self.method.state["N"], self.method.state["D"]
        engine = self.policy.engine
        if (cfg.kind == "opq" or self.index_kind == "ivf"
                or cfg.policy is not None or t_end is not None):
            engine = "stream"       # only the streaming engine serves these
        qe = {key: jnp.asarray(v) for key, v in qe.items()}
        cand_per_q = np.full(nq, N, np.float64)
        passed = dmin = report = coverage = dims_read = None
        n_anchor = 0                # two_stage completes k anchors per query
        if self.mesh is None:
            if engine == "two_stage":
                out = two_stage_topk(
                    self._state, jnp.asarray(ql), jnp.asarray(qt), cfg, qe)
                n_anchor = nq * k
            else:
                from repro.core.stream_engine import build_stream_blocks
                if self._blocks is None:
                    # pad+reshape of the whole corpus happens once per
                    # materialization, not per query batch
                    self._blocks = build_stream_blocks(
                        self._state, self.policy.row_block,
                        dim_groups=self._groups)
                blocks, st = self._blocks, self._state
                if self.delta_rows:
                    if self._delta_dirty or self._delta_blocks is None:
                        self._build_delta()
                    blocks = self._delta_blocks
                    # thread the combined tail-norm min so the ddcres screen
                    # stays as loose as fitted (stream_engine tail_min)
                    st = dict(self._state, tail_min=jnp.minimum(
                        self._state["tail_sq"].min(),
                        jnp.float32(self._delta_tail_min)))
                probe = None
                if self.index_kind == "ivf":
                    probed, cand_per_q = self._probe(Q, nprobe)
                    probe = jnp.asarray(probed)
                    nd = self.delta_rows
                    if nd:
                        # delta rows are probe candidates too when their
                        # partition was selected
                        cand_per_q = cand_per_q + (
                            self._delta_parts[None, :nd, None]
                            == probed[:, None, :]).any(-1).sum(1)
                out = stream_topk(
                    st, jnp.asarray(ql), jnp.asarray(qt), cfg, qe,
                    probe, blocks=blocks, deadline_ts=t_end,
                    block_group=self.policy.anytime_block_group)
            # one batched transfer: the post-jit slices (and the adaptive
            # report) are tiny lazy dispatches — converting them one
            # np.asarray at a time serializes a sync per output
            out = jax.device_get(out)
            if engine == "two_stage":
                d, i, surv = out
            elif cfg.policy is not None:
                d, i, surv, passed, dmin, dims_read, report = out
            elif t_end is not None:
                d, i, surv, passed, dmin, dims_read, coverage = out
            else:
                d, i, surv, passed, dmin, dims_read = out
            if coverage is not None:
                # partial scans only touched this fraction of the corpus:
                # charge candidate work pro rata so pruning stats stay honest
                cand_per_q = cand_per_q * coverage
        else:
            if cfg not in self._mesh_fns:
                self._mesh_fns[cfg] = jax.jit(
                    make_distributed_topk(self.mesh, cfg,
                                          tuple(self.mesh.axis_names),
                                          extra_state=self._mesh_extra_state,
                                          engine=engine,
                                          n_rows=self._n_main))
            d, i, surv, dmin = self._mesh_fns[cfg](*self._shard_args,
                                                   jnp.asarray(ql),
                                                   jnp.asarray(qt), qe)
            surv = np.asarray(surv)     # real completions, psum'd over shards
            if engine == "two_stage":
                n_anchor = nq * k * int(np.prod(tuple(self.mesh.shape.values())))
        jax.block_until_ready(d)
        stats = ScanStats(n_dco=int(cand_per_q.sum()),
                          dims_total=float((cand_per_q * D).sum()))
        if cfg.kind == "fdscan":
            stats.dims_scanned = stats.dims_total
        elif cfg.kind == "opq":
            # PQ screening charges n_sub 'dims' per candidate (as the host
            # rule does); survivors complete the full D original dims
            n_sub = int(self._dstate["books"].shape[0])
            stats.dims_scanned = (float((cand_per_q * n_sub).sum())
                                  + float(surv.sum()) * D)
            stats.extra[EXTRA_SURVIVORS_MEAN] = float(surv.mean())
            stats.extra[EXTRA_SCREEN_PASS_MEAN] = float(np.asarray(passed).mean())
            self._certify(stats, d, dmin)
        else:
            # stage 1 streams d1 dims for every candidate row; stage 2 (plus
            # the two-stage engine's k anchor completions) streams the tail
            # for the ACTUAL survivors
            stats.dims_scanned = (float((cand_per_q * self._d1).sum())
                                  + float(surv.sum() + n_anchor) * (D - self._d1))
            stats.extra[EXTRA_SURVIVORS_MEAN] = float(surv.mean())
            if passed is not None:
                stats.extra[EXTRA_SCREEN_PASS_MEAN] = float(np.asarray(passed).mean())
            self._certify(stats, d, dmin)
        if dims_read is not None:
            # the streaming scan measured its own reads (per-group alive
            # counts + completed tails, DESIGN.md §8): trust them over the
            # stage-shaped formula — under PDX early exit the formula
            # overstates lead reads, under adaptive fallback it understates
            stats.dims_scanned = float(
                np.asarray(dims_read, np.float64).sum())
        stats.extra[EXTRA_DIMS_READ_MEAN] = (
            stats.dims_scanned / max(stats.n_dco, 1))
        if report is not None:
            stats.extra[EXTRA_FALLBACK_BLOCKS] = float(
                np.asarray(report["fallback_blocks"]).mean())
            stats.extra[EXTRA_EST_SAVED_FLOPS] = float(
                np.asarray(report["est_saved_flops"]).sum())
            stats.extra[EXTRA_RULE_TIMELINE] = [
                float(v) for v in np.asarray(report["rule_timeline"])]
        # anytime coverage (DESIGN.md §7): every query of the batch shares
        # the scanned-block fraction; partial scans are uncertified even if
        # the dropped-estimate certificate held over the scanned prefix
        cov_arr = np.full(nq, 1.0 if coverage is None else coverage,
                          np.float32)
        stats.extra[EXTRA_COVERAGE] = cov_arr
        mask = stats.extra.get(EXTRA_UNCERTIFIED_MASK)
        if mask is not None and coverage is not None and coverage < 1.0:
            stats.extra[EXTRA_UNCERTIFIED_MASK] = mask | (cov_arr < 1.0)
            stats.extra[EXTRA_UNCERTIFIED_QUERIES] = float(
                stats.extra[EXTRA_UNCERTIFIED_MASK].mean())
        return (np.asarray(d, np.float32), np.asarray(i, np.int64), stats)

    @staticmethod
    def _certify(stats, d, dmin):
        """Streaming-engine exactness certificate: a query is certified iff
        every estimate the per-block completion budget dropped exceeds its
        returned k-th distance (so no true neighbor can have been truncated;
        DESIGN.md §4).  For estimator rules the stat is advisory."""
        if dmin is None:
            return
        fail = np.asarray(dmin) <= np.asarray(d)[:, -1]
        stats.extra[EXTRA_UNCERTIFIED_QUERIES] = float(fail.mean())
        stats.extra[EXTRA_UNCERTIFIED_MASK] = fail


def make_backend(name: str, method, index_kind: str, index, policy, *, mesh=None):
    """Construct the executor for ``name`` ('host' or 'jax')."""
    if name == "host":
        if mesh is not None:
            raise ValueError("mesh sharding is a jax-backend feature")
        return HostBackend(method, index_kind, index, policy)
    if name == "jax":
        return JaxBackend(method, index_kind, index, policy, mesh=mesh)
    raise ValueError(f"unknown backend {name!r} (expected 'host' or 'jax')")
