"""Value types of the facade: scheduling policy, search results, stat keys."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import (EXTRA_AUDIT_RECALL, EXTRA_BREAKER_STATE,
                               EXTRA_COVERAGE, EXTRA_DEGRADED,
                               EXTRA_DIMS_READ_MEAN, EXTRA_DRIFT_SCORE,
                               EXTRA_EST_SAVED_FLOPS, EXTRA_FALLBACK_BLOCKS,
                               EXTRA_HEDGED, EXTRA_REPLICA,
                               EXTRA_RULE_TIMELINE, EXTRA_SCREEN_PASS_MEAN,
                               EXTRA_SURVIVORS_MEAN, EXTRA_UNCERTIFIED_MASK,
                               EXTRA_UNCERTIFIED_QUERIES, ScanStats,
                               make_schedule)

#: The canonical ``SearchResult.stats.extra`` keys, with their semantics.
#: Both backends report batch telemetry under these names and only these
#: names (the constants live in ``core.engine`` so the engines and the
#: facade share one spelling; this dict is the normative documentation).
STAT_EXTRA_KEYS: dict = {
    EXTRA_SURVIVORS_MEAN:
        "Mean rows per query whose exact distance was completed (stage-2 "
        "work actually done; measured, not a capacity bound).",
    EXTRA_SCREEN_PASS_MEAN:
        "Mean rows per query that passed the screening rule.  On the host "
        "path this equals survivors_mean (no completion budget); on the jax "
        "streaming path survivors are additionally capped per block by "
        "block_capacity, and under the adaptive policy fallback blocks "
        "complete rows the (shadow) screen rejected.",
    EXTRA_UNCERTIFIED_QUERIES:
        "Fraction of queries whose streaming-engine exactness certificate "
        "failed: some estimate dropped by the per-block completion budget "
        "was <= the returned k-th distance, so a true neighbor may have "
        "been truncated (DESIGN.md §4-5).  0.0 on the host path, which "
        "completes every survivor.  Advisory for estimator rules.",
    EXTRA_FALLBACK_BLOCKS:
        "Adaptive policy only: mean candidate blocks per query served by "
        "the certified fdscan fallback instead of the configured rule.",
    EXTRA_EST_SAVED_FLOPS:
        "Adaptive policy only: cost-model estimate of FLOPs saved by "
        "screening vs an always-fdscan baseline, summed over the batch "
        "(2 FLOPs per row-dim avoided, minus modeled overhead; negative "
        "when screening was pure loss).",
    EXTRA_RULE_TIMELINE:
        "Adaptive policy only: per block index, the fraction of the batch "
        "(query chunks on jax, queries on host) served by the fallback — "
        "the scan-time story of which rule was active when.",
    EXTRA_UNCERTIFIED_MASK:
        "Per-query bool array: row i is True iff query i's exactness "
        "certificate failed (the per-query view of uncertified_queries; "
        "serving.SearchService threads it into per-request results).  All "
        "False on the host path; absent on the legacy two_stage engine, "
        "which has no per-block certificate.",
    EXTRA_DIMS_READ_MEAN:
        "Mean dimensions actually touched per candidate row (screening "
        "reads plus exact-completion tails), the direct evidence that "
        "early exit is firing — compare against D (no pruning) and the "
        "schedule's d1/stage dims.  Measured from the scan itself on the "
        "stream and host paths (per-group/per-stage alive counts, "
        "DESIGN.md §8); formula-derived on the legacy two_stage engine "
        "and the mesh path (screen dims + completed tails).",
    EXTRA_DRIFT_SCORE:
        "Guardrail sessions only (SchedulePolicy.guardrails armed): the "
        "drift sentinel's EWMA-smoothed query-drift score for this batch, "
        "in [0, 1] — 0 = queries look like the reference corpus sample, "
        "1 = maximal spectral/norm deviation (DESIGN.md §9).",
    EXTRA_AUDIT_RECALL:
        "Guardrail sessions only: EWMA of the online recall audit — a "
        "deterministic ~1/64 query sample shadow-re-executed through the "
        "certified full scan, top-k overlap vs the served answer.  1.0 "
        "until the first audit fires.",
    EXTRA_BREAKER_STATE:
        "Guardrail sessions only: the circuit-breaker state that actually "
        "served this batch — 'closed' (screening), 'open' (demoted to the "
        "certified full scan), or 'half_open' (screening canary probe "
        "during recovery).",
    EXTRA_COVERAGE:
        "Per-query float32 array: fraction of candidate blocks actually "
        "scanned for query i (anytime search, DESIGN.md §7).  1.0 "
        "everywhere unless the search ran with a ``deadline_s`` that "
        "expired mid-scan; any value < 1.0 also sets that query's "
        "uncertified_mask bit, since an unscanned block may hold a true "
        "neighbor.  On the jax path the whole batch advances together, so "
        "coverage is uniform across queries; the host path checks the "
        "deadline per query, so later queries can report 0.0.  The replica "
        "tier (DESIGN.md §10) extends the same key *spatially*: under "
        "shard loss, coverage is the fraction of corpus rows the surviving "
        "shards actually hold, again with the certificate withdrawn.",
    EXTRA_DEGRADED:
        "Replica tier only (serving.ReplicatedService, DESIGN.md §10): 1.0 "
        "when this batch was answered from a strict subset of shards — at "
        "least one shard was down after retries, so coverage < 1 and every "
        "query's certificate is withdrawn.  0.0 on fully-covered batches.",
    EXTRA_REPLICA:
        "Replica tier only: index of the replica that served this batch "
        "(mode='replicate'; the hedge winner when a hedge fired), or -1.0 "
        "for a sharded fan-out, where every live shard contributed.",
    EXTRA_HEDGED:
        "Replica tier only: 1.0 when a hedged duplicate dispatch raced "
        "this batch (the primary exceeded its adaptive hedge delay), else "
        "0.0 — whether the hedge *won* is in health()'s hedge_wins.",
}


@dataclasses.dataclass(frozen=True)
class SchedulePolicy:
    """How a session stages its DCO screening, on both backends.

    Host (staged numpy scan): ``delta0``/``delta_d``/``max_stages`` set the
    paper's (Delta_0, Delta_d) stage dims.  Device: ``d1`` is the stage-1
    lead width, ``query_chunk`` the lax.map batch granularity, ``tau_slack``
    the extra slack on the certified threshold.  ``engine`` picks the device
    engine — ``"stream"`` (default; block-fused scan with a running top-k,
    core.stream_engine) or ``"two_stage"`` (legacy one-shot engine that
    materializes the (query_chunk, N) estimate matrix; ``capacity`` is its
    survivor budget).  Streaming knobs: ``row_block`` corpus rows per scan
    step (bigger = fewer merges, more VMEM/HBM per tile), ``block_capacity``
    survivors tail-completed per block per query (must comfortably exceed k;
    the per-block analogue of ``capacity``), ``use_kernel`` routes stage 1
    through the Pallas kernels (None = only on TPU).  See DESIGN.md §4.

    ``dim_groups`` > 1 selects the PDX vertical layout (DESIGN.md §8): each
    row block stores its lead dims in that many contiguous groups and the
    streaming scan refines candidates group by group, freezing each one
    whose running partial crosses the certified tau — with the group-0
    R-cut's best dropped estimate folded into the exactness certificate, so
    PDX scans stay certified by construction.  Ignored (forced to 1) for
    methods without a partial-distance screen (FDScanning, DDCopq), by the
    two_stage engine, and on the mesh path.  The host backend mirrors it
    automatically: lower-bound methods screen via incremental
    ``partial_range`` group reads whenever stages are staged.
    ``group_capacity`` bounds the candidates each query carries past group 0
    on the jnp path (0 = auto: max(4*block_capacity, 512)); raise it if
    ``uncertified_queries`` reports R-cut drops.

    ``delta_merge_threshold`` governs the jax backend's LSM-style write path
    (DESIGN.md §6): ``add()`` appends rows to a small delta segment that is
    scanned alongside the cached main block layout (same running tau), and
    the main layout is only re-materialized (a "merge") once the delta holds
    more than this many rows.  0 disables the delta path entirely — every
    insert re-materializes, the pre-PR-6 behavior.

    ``adaptive=True`` arms the adaptive DCO policy (DESIGN.md §5): the
    engines watch per-block survivor fractions and degrade the configured
    rule to the certified fdscan fallback while screening is predicted
    net-negative, recovering when it pays again.  ``fallback_margin`` is
    how much cheaper than a full scan the cost model must predict screening
    to be before it is trusted (>1 = demand headroom; raise it to fall back
    earlier).  Served by the streaming jax engine and the host flat/IVF
    scan; ignored by host HNSW walks and rejected on the mesh path.

    ``wal_max_bytes`` rotates the crash-safe delta WAL (DESIGN.md §7/§10):
    once the active segment reaches this many bytes, later ``add()``
    appends open a fresh numbered segment (``.wal.0001``, ...), replayed in
    order on load with per-segment torn-tail truncation — bounding the
    single-file size (and the blast radius of one torn tail) between
    snapshots.  0 = never rotate, the single-segment pre-PR-10 behavior.

    ``anytime_block_group`` is the deadline-check granularity of anytime
    search on the jax backend (DESIGN.md §7): a ``deadline_s`` search runs
    the streaming scan this many row blocks at a time, syncing with the
    host between groups to test the wall clock.  Smaller = finer deadline
    resolution but more device/host round-trips; the first group always
    completes, so a result is returned even for an already-expired
    deadline.  ``faults`` optionally scopes a ``repro.testing.FaultPlan``
    to sessions built with this policy (chaos testing; see
    ``repro.testing.faults``).

    ``guardrails`` arms the guardrail layer (DESIGN.md §9): pass a
    ``repro.core.guardrails.GuardrailConfig`` (or ``True`` for defaults)
    and the session fits a query-drift sentinel at open time, shadow-audits
    a deterministic ~1/64 query sample against the certified full scan,
    and runs a per-(method, backend) circuit breaker that demotes DCO
    screening to the certified full-scan body while drift plus audit
    evidence says screening can't be trusted — recovering via half-open
    canary probes.  Supported for scan-shaped searches (index 'flat' or
    'ivf') on both backends; rejected for HNSW (a graph walk has no
    certified fallback) and on the mesh path; a no-op for FDScanning
    sessions, which are already the fallback.
    """

    delta0: int = 32
    delta_d: int = 64
    max_stages: int = 4
    d1: int = 128
    capacity: int = 2048
    query_chunk: int = 16
    tau_slack: float = 1.0
    engine: str = "stream"
    row_block: int = 4096
    block_capacity: int = 128
    use_kernel: bool | None = None
    dim_groups: int = 1
    group_capacity: int = 0
    adaptive: bool = False
    fallback_margin: float = 1.5
    delta_merge_threshold: int = 4096
    wal_max_bytes: int = 0
    anytime_block_group: int = 8
    faults: object | None = None
    guardrails: object | None = None

    def stage_dims(self, D: int) -> list:
        """Host screening stage dims for dimensionality ``D`` (the paper's
        (Delta_0, Delta_d) schedule, capped at ``max_stages``)."""
        return make_schedule(D, delta0=self.delta0, delta_d=self.delta_d,
                             max_stages=self.max_stages)


@dataclasses.dataclass
class SearchResult:
    """Batched search output: row ``i`` answers query ``i``.

    ``dists`` are squared Euclidean distances (the monotone form every method
    computes in); ``stats`` aggregates DCO work over the whole batch (see
    ``STAT_EXTRA_KEYS`` for the ``stats.extra`` telemetry); ``wall_time_s``
    is the facade-measured end-to-end time including online query
    pre-processing.
    """

    dists: np.ndarray          # (nq, k) float32
    ids: np.ndarray            # (nq, k) int64
    stats: ScanStats
    wall_time_s: float
    backend: str

    @property
    def nq(self) -> int:
        """Number of queries answered."""
        return int(self.ids.shape[0])

    @property
    def k(self) -> int:
        """Neighbors returned per query."""
        return int(self.ids.shape[1])

    @property
    def qps(self) -> float:
        """Queries per second over the facade-measured wall time."""
        return self.nq / max(self.wall_time_s, 1e-12)
