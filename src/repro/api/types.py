"""Value types of the facade: scheduling policy and search results."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import ScanStats, make_schedule


@dataclasses.dataclass(frozen=True)
class SchedulePolicy:
    """How a session stages its DCO screening, on both backends.

    Host (staged numpy scan): ``delta0``/``delta_d``/``max_stages`` set the
    paper's (Delta_0, Delta_d) stage dims.  Device (two-stage JAX engine):
    ``d1`` is the stage-1 lead width, ``capacity`` the per-query stage-2
    survivor budget, ``query_chunk`` the lax.map batch granularity, and
    ``tau_slack`` the extra slack on the certified threshold.
    """

    delta0: int = 32
    delta_d: int = 64
    max_stages: int = 4
    d1: int = 128
    capacity: int = 2048
    query_chunk: int = 16
    tau_slack: float = 1.0

    def stage_dims(self, D: int) -> list:
        return make_schedule(D, delta0=self.delta0, delta_d=self.delta_d,
                             max_stages=self.max_stages)


@dataclasses.dataclass
class SearchResult:
    """Batched search output: row ``i`` answers query ``i``.

    ``dists`` are squared Euclidean distances (the monotone form every method
    computes in); ``stats`` aggregates DCO work over the whole batch;
    ``wall_time_s`` is the facade-measured end-to-end time including online
    query pre-processing.
    """

    dists: np.ndarray          # (nq, k) float32
    ids: np.ndarray            # (nq, k) int64
    stats: ScanStats
    wall_time_s: float
    backend: str

    @property
    def nq(self) -> int:
        return int(self.ids.shape[0])

    @property
    def k(self) -> int:
        return int(self.ids.shape[1])

    @property
    def qps(self) -> float:
        return self.nq / max(self.wall_time_s, 1e-12)
