"""Value types of the facade: scheduling policy and search results."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import ScanStats, make_schedule


@dataclasses.dataclass(frozen=True)
class SchedulePolicy:
    """How a session stages its DCO screening, on both backends.

    Host (staged numpy scan): ``delta0``/``delta_d``/``max_stages`` set the
    paper's (Delta_0, Delta_d) stage dims.  Device: ``d1`` is the stage-1
    lead width, ``query_chunk`` the lax.map batch granularity, ``tau_slack``
    the extra slack on the certified threshold.  ``engine`` picks the device
    engine — ``"stream"`` (default; block-fused scan with a running top-k,
    core.stream_engine) or ``"two_stage"`` (legacy one-shot engine that
    materializes the (query_chunk, N) estimate matrix; ``capacity`` is its
    survivor budget).  Streaming knobs: ``row_block`` corpus rows per scan
    step (bigger = fewer merges, more VMEM/HBM per tile), ``block_capacity``
    survivors tail-completed per block per query (must comfortably exceed k;
    the per-block analogue of ``capacity``), ``use_kernel`` routes stage 1
    through the Pallas kernels (None = only on TPU).  See DESIGN.md §4.
    """

    delta0: int = 32
    delta_d: int = 64
    max_stages: int = 4
    d1: int = 128
    capacity: int = 2048
    query_chunk: int = 16
    tau_slack: float = 1.0
    engine: str = "stream"
    row_block: int = 4096
    block_capacity: int = 128
    use_kernel: bool | None = None

    def stage_dims(self, D: int) -> list:
        return make_schedule(D, delta0=self.delta0, delta_d=self.delta_d,
                             max_stages=self.max_stages)


@dataclasses.dataclass
class SearchResult:
    """Batched search output: row ``i`` answers query ``i``.

    ``dists`` are squared Euclidean distances (the monotone form every method
    computes in); ``stats`` aggregates DCO work over the whole batch;
    ``wall_time_s`` is the facade-measured end-to-end time including online
    query pre-processing.
    """

    dists: np.ndarray          # (nq, k) float32
    ids: np.ndarray            # (nq, k) int64
    stats: ScanStats
    wall_time_s: float
    backend: str

    @property
    def nq(self) -> int:
        return int(self.ids.shape[0])

    @property
    def k(self) -> int:
        return int(self.ids.shape[1])

    @property
    def qps(self) -> float:
        return self.nq / max(self.wall_time_s, 1e-12)
