"""``repro.api`` — the unified vector-search facade.

One batched Session API over the host (numpy staged-scan) and JAX/Pallas
(two-stage device) backends:

    from repro.api import open_index
    sess = open_index(X, index="ivf", method="ADSampling", backend="host")
    res = sess.search(Q, k=10, nprobe=16)
    print(res.ids, res.qps, res.stats.pruning_ratio)

See README.md for the method/backend support table.
"""
from repro.api.persistence import DeltaWAL, IndexLoadError  # noqa: F401
from repro.api.session import (INDEX_KINDS, METHODS, SearchSession,  # noqa: F401
                               open_index)
from repro.api.types import (STAT_EXTRA_KEYS, SchedulePolicy,  # noqa: F401
                             SearchResult)
from repro.core.engine import QueryBatch, ScanStats  # noqa: F401
from repro.core.guardrails import (BREAKER_STATES, Guardrail,  # noqa: F401
                                   GuardrailConfig)
from repro.serving.replica import (ReplicaDispatchError,  # noqa: F401
                                   ReplicaPolicy, ReplicatedService,
                                   open_replicated)
from repro.serving.search_service import (SearchRequest,  # noqa: F401
                                          SearchService)
