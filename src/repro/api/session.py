"""The facade: ``open_index(...)`` -> ``SearchSession``.

One entrypoint owns the whole lifecycle the paper's comparison needs —
method fitting/training, index construction, backend dispatch — so swapping
a DCO method, an index, or the host/device backend is a keyword argument,
not a different calling convention:

    sess = open_index(X, index="ivf", method="DADE", backend="host")
    res = sess.search(Q, k=10, nprobe=16)        # batched; res.ids (nq, k)
    sess.add(X_new)                              # dynamic inserts, no refit
    sess.save("idx.bin"); sess = SearchSession.load("idx.bin")
"""
from __future__ import annotations

import time

import numpy as np

from repro.api.backends import make_backend
from repro.api.types import SchedulePolicy, SearchResult
from repro.core.methods import ALL_METHODS, make_method
from repro.search.hnsw import HNSWIndex
from repro.search.ivf import IVFIndex

INDEX_KINDS = ("flat", "ivf", "hnsw")
#: facade name of every paper method -> backends that can serve it natively.
#: (Methods not listed under "jax" still run there via the exact lower-bound
#: fallback of their ``device_state()`` export.)
METHODS = tuple(ALL_METHODS)


class SearchSession:
    """A fitted method + built index + backend, behind batched calls."""

    def __init__(self, method, index_kind: str, index, backend: str = "host",
                 policy: SchedulePolicy | None = None, *, mesh=None):
        if index_kind not in INDEX_KINDS:
            raise ValueError(f"index must be one of {INDEX_KINDS}, got {index_kind!r}")
        self.method = method
        self.index_kind = index_kind
        self.index = index
        self.policy = policy if policy is not None else SchedulePolicy()
        self.backend = make_backend(backend, method, index_kind, index,
                                    self.policy, mesh=mesh)
        self.last_write_mode: str | None = None   # set by add()
        self.wal = None   # DeltaWAL once save()/load() ties a path to us

    # -- introspection -------------------------------------------------------
    @property
    def n(self) -> int:
        """Number of indexed vectors."""
        return int(self.method.state["N"])

    @property
    def dim(self) -> int:
        """Vector dimensionality."""
        return int(self.method.state["D"])

    @property
    def backend_name(self) -> str:
        """Executing backend: ``"host"`` or ``"jax"``."""
        return self.backend.name

    # -- online --------------------------------------------------------------
    def search(self, Q, k: int = 10, *, nprobe: int = 16, ef: int = 64,
               deadline_s: float | None = None) -> SearchResult:
        """Batched top-k for all rows of ``Q``; one online prep for the whole
        batch (the paper's O(D^2) per-query rotation, amortized).

        ``deadline_s`` arms anytime search (DESIGN.md §7): the scan stops
        after the last row-block (jax: block group) that finishes within
        ``deadline_s`` seconds of wall time and returns the running top-k as
        a partial result.  Partial queries report ``coverage < 1.0`` and a
        set ``uncertified_mask`` bit in ``result.stats.extra``; with a
        generous deadline the result is bit-identical to the non-deadline
        path.  Flat/IVF only (HNSW walks and mesh scans reject it)."""
        Q = np.atleast_2d(np.asarray(Q))
        if Q.dtype.kind not in "fiu":
            raise ValueError(
                f"search(): expected a numeric query array, got dtype {Q.dtype}")
        Q = np.ascontiguousarray(Q, np.float32)
        if not np.isfinite(Q).all():
            bad = int((~np.isfinite(Q).all(axis=1)).sum())
            raise ValueError(
                f"search(): {bad} of {Q.shape[0]} queries contain NaN/Inf "
                "values; distances to non-finite queries are meaningless "
                "and would poison the running top-k threshold")
        if deadline_s is not None and deadline_s <= 0.0:
            raise ValueError(
                f"search(): deadline_s must be > 0 (got {deadline_s}); the "
                "engines always finish at least one block group, so a "
                "non-positive budget cannot mean 'return nothing'")
        t0 = time.perf_counter()
        dists, ids, stats = self.backend.search(Q, k, nprobe=nprobe, ef=ef,
                                                deadline_s=deadline_s)
        return SearchResult(dists, ids, stats, time.perf_counter() - t0,
                            self.backend.name)

    def add(self, Xnew) -> "SearchSession":
        """Dynamic inserts (paper §V-E): extend the fitted method state
        without refitting transforms, then link/assign into the index.

        On the jax backend inserts below ``policy.delta_merge_threshold``
        rows land in a delta segment scanned alongside the cached main block
        layout (no re-materialization; DESIGN.md §6); the last write mode
        taken is readable as ``session.last_write_mode``.

        When the session is tied to a snapshot path (after ``save()`` or
        ``load()``), the rows are first written to the crash-safe delta WAL
        (fsync'd, before any state changes; DESIGN.md §7) — a crash at any
        point after ``add()`` returns loses nothing, and a crash mid-write
        tears only a frame that was never acknowledged."""
        Xnew = np.atleast_2d(np.asarray(Xnew))
        if Xnew.dtype.kind not in "fiu":
            raise ValueError(
                f"add(): expected a numeric array, got dtype {Xnew.dtype}")
        if Xnew.ndim != 2:
            raise ValueError(
                f"add(): expected (n, D) vectors, got shape {Xnew.shape}")
        if Xnew.shape[1] != self.dim:
            raise ValueError(
                f"add(): vectors have dimension {Xnew.shape[1]}, but this "
                f"index was built with D={self.dim}")
        Xnew = np.ascontiguousarray(Xnew, np.float32)
        if not np.isfinite(Xnew).all():
            bad = int((~np.isfinite(Xnew).all(axis=1)).sum())
            raise ValueError(
                f"add(): {bad} of {Xnew.shape[0]} rows contain NaN/Inf "
                "values; a non-finite corpus row poisons every distance "
                "computed against it (and the streaming engine's running "
                "tau), so it is rejected before any state or WAL write")
        if self.wal is not None:
            from repro.testing import faults
            self.wal.append(Xnew, self.n, plan=faults.active(self.policy))
        return self._apply_add(Xnew)

    def _apply_add(self, Xnew: np.ndarray) -> "SearchSession":
        """The state mutation of :meth:`add`, sans validation and WAL
        logging — the WAL's ``replay()`` calls this directly so replayed
        frames are not re-logged."""
        parts = None
        if self.index_kind == "hnsw":
            # insert_batch appends to the method itself, then links
            self.index.insert_batch(self.method, Xnew,
                                    schedule=self.policy.stage_dims(self.dim))
        else:
            start = self.n
            self.method.append(Xnew)
            if self.index_kind == "ivf":
                parts = self.index.insert(
                    np.arange(start, start + Xnew.shape[0]), Xnew)
        self.last_write_mode = self.backend.notify_append(
            Xnew.shape[0], parts=parts)
        return self

    def guardrails(self) -> dict | None:
        """Guardrail snapshot (DESIGN.md §9) when the session was opened
        with ``SchedulePolicy(guardrails=...)``: breaker state, drift/audit
        EWMAs, audit counters, and the transition log.  ``None`` when no
        guardrail is armed (including FDScanning sessions, which are
        already the certified fallback)."""
        g = getattr(self.backend, "guardrail", None)
        return None if g is None else g.report()

    def serve(self, **kwargs) -> "SearchService":
        """Wrap this session in a continuous-batching serving front
        (``repro.serving.SearchService``); kwargs are its knobs
        (slots/k/nprobe/...)."""
        from repro.serving.search_service import SearchService
        return SearchService(self, **kwargs)

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> None:
        """Persist the fitted state + index to ``path`` (api.persistence)
        and arm the crash-safe delta WAL at ``path + ".wal"`` — later
        ``add()`` calls are logged there and survive a crash (the log is
        cleared first: this snapshot supersedes it)."""
        from repro.api.persistence import save_session
        save_session(self, path)

    @classmethod
    def load(cls, path, *, backend: str | None = None, mesh=None) -> "SearchSession":
        """Rebuild a saved session and replay its delta WAL (inserts made
        after the snapshot); ``backend``/``mesh`` may be overridden.
        Raises ``api.IndexLoadError`` on an unreadable snapshot."""
        from repro.api.persistence import load_session
        return load_session(path, backend=backend, mesh=mesh)


def open_index(X=None, *, index: str = "flat", method: str = "DADE",
               backend: str | None = None,
               schedule: SchedulePolicy | None = None,
               method_params: dict | None = None,
               index_params: dict | None = None,
               train_queries=None, train_k: int = 10,
               seed: int = 0, mesh=None, serving: bool = False,
               serving_params: dict | None = None, path=None):
    """Fit ``method`` on ``X``, build ``index``, and return a ready session.

    ``method`` is one of the paper's 8 (``repro.api.METHODS``); training-based
    methods (DDCpca/DDCopq) are trained on ``train_queries`` (default: a
    sample of X rows) for ``k=train_k``.  ``schedule`` tunes staging on both
    backends (default ``backend="host"``) — including
    ``SchedulePolicy(dim_groups=...)``, which switches the jax streaming
    engine to the PDX vertical layout with per-group early exit and makes
    the host scan read lower-bound stages incrementally (DESIGN.md §8);
    ``mesh`` (jax backend only) shards
    the corpus for a distributed global top-k.  ``serving=True`` wraps the
    session in a continuous-batching ``repro.serving.SearchService``
    (``serving_params`` are its knobs) and returns that instead.

    ``path`` ties the session to a snapshot file (DESIGN.md §7).  With
    ``X=None`` the session is *loaded* from ``path`` — snapshot plus a
    replay of its delta WAL, so inserts acknowledged after the last
    ``save()`` survive a crash (``IndexLoadError`` on unreadable files).
    With both given, the fresh index is immediately saved to ``path``,
    arming the WAL for every later ``add()``.
    """
    if X is None:
        if path is None:
            raise ValueError("open_index(): pass vectors X to build an "
                             "index, or path= to load a saved one")
        sess = SearchSession.load(path, backend=backend, mesh=mesh)
        if serving:
            return sess.serve(**(serving_params or {}))
        return sess
    backend = backend if backend is not None else "host"
    X = np.ascontiguousarray(np.atleast_2d(X), np.float32)
    policy = schedule if schedule is not None else SchedulePolicy()
    if method not in ALL_METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    # fail before paying for an index the backend can't serve
    if backend == "jax" and index == "hnsw":
        raise ValueError(
            f"backend='jax' serves index='flat' or 'ivf' (got {index!r}); "
            "HNSW graph walks are host-side indexes")
    if backend == "jax" and index == "ivf" and mesh is not None:
        raise ValueError(
            "device IVF probing is single-device; mesh-shard a flat corpus "
            "instead")
    m = make_method(method, **{"seed": seed, **(method_params or {})})
    m.fit(X)
    if m.needs_training:
        if train_queries is None:
            rng = np.random.default_rng(seed)
            train_queries = X[rng.choice(X.shape[0], min(24, X.shape[0]),
                                         replace=False)]
        m.train(np.asarray(train_queries, np.float32), train_k,
                policy.stage_dims(X.shape[1]))

    params = dict(index_params or {})
    if index == "flat":
        idx = None
    elif index == "ivf":
        params.setdefault("n_list", 64)
        idx = IVFIndex(**params).build(X)
    elif index == "hnsw":
        idx = HNSWIndex(**params).build(X, method=m,
                                        schedule=policy.stage_dims(X.shape[1]))
    else:
        raise ValueError(f"index must be one of {INDEX_KINDS}, got {index!r}")
    sess = SearchSession(m, index, idx, backend, policy, mesh=mesh)
    if path is not None:
        sess.save(path)
    if serving:
        return sess.serve(**(serving_params or {}))
    return sess
