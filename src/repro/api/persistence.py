"""Save/load of fitted sessions, plus the crash-safe delta WAL.

The fitted state is plain numpy (method state dicts, index arrays), so a
single pickle payload round-trips everything the online path needs — fit
once, serve anywhere.  Device arrays are NOT persisted; the jax backend
re-materializes them lazily from ``device_state()`` on first search.
Snapshots carry a crc32 integrity trailer (``b"SNAP" | uint64 len |
uint32 crc``) verified *before* unpickling, so a bit-rotted or truncated
file fails loudly as ``IndexLoadError`` instead of unpickling garbage.

Dynamic inserts between snapshots are covered by :class:`DeltaWAL`
(DESIGN.md §7): a session saved to ``path`` arms an append-only log at
``path + ".wal"`` and every later ``add()`` writes its rows there —
*before* applying them, fsync'd — as one self-describing frame::

    b"DWAL" | uint32 payload_len | uint32 crc32(payload) | payload

where the payload is an npz archive of ``{n_before, rows}``.  ``n_before``
(the corpus size the frame was logged against) makes replay idempotent:
loading a snapshot replays only frames with ``n_before >= session.n``, so
a double replay — or a replay against a snapshot that already absorbed the
frame via a later ``save()`` — applies nothing twice.  A crash mid-write
leaves a torn tail frame; the reader detects it by length/CRC, drops it
with a warning, and keeps everything before it.  A torn frame was never
acknowledged to the caller (the write happens before ``add()`` returns),
so dropping it loses no acknowledged insert.  ``save()`` clears the log:
the new snapshot supersedes it.

Both the snapshot and the WAL are written *atomically with respect to
crashes* (DESIGN.md §10): ``save_session`` writes a tmp file, fsyncs it,
``os.replace``s it over the target, and fsyncs the parent directory — a
crash at any point leaves either the old snapshot or the new one, never a
half-written hybrid (``testing.FaultPlan(crash_save=...)`` injects the
worst point, after the tmp write and before the rename).  ``clear()``
empties the log the same way.  With ``SchedulePolicy(wal_max_bytes=...)``
set, the log *rotates*: once the active segment reaches the cap, later
appends open numbered segments (``.wal.0001``, ...), replayed in order
with per-segment torn-tail truncation, and ``clear()`` removes them all.

Load failures raise :class:`IndexLoadError` naming the path and the likely
cause, instead of leaking pickle/OS internals.
"""
from __future__ import annotations

import io
import os
import pickle
import struct
import warnings
import zlib

import numpy as np

FORMAT_VERSION = 1

_WAL_MAGIC = b"DWAL"
_WAL_HEADER = struct.Struct("<II")     # payload length, crc32(payload)

# snapshot integrity trailer, appended AFTER the pickle payload:
#     payload | b"SNAP" | uint64 payload_len | uint32 crc32(payload)
# load_session verifies it BEFORE unpickling — a silently bit-rotted or
# truncated snapshot fails with a named cause instead of unpickling garbage
# (or worse, unpickling something plausible).
_SNAP_MAGIC = b"SNAP"
_SNAP_TRAILER = struct.Struct("<QI")   # payload length, crc32(payload)


class IndexLoadError(RuntimeError):
    """A saved index could not be loaded.  Carries the offending ``path``
    and a one-line likely cause so serving code can log/alert usefully."""

    def __init__(self, path, cause: str):
        self.path = str(path)
        self.cause = cause
        super().__init__(f"cannot load index from {self.path}: {cause}")


def wal_path(path) -> str:
    """The delta-WAL file tied to snapshot ``path``."""
    return f"{path}.wal"


def _fsync_dir(dirpath) -> None:
    """fsync a directory so a rename/unlink inside it is durable (best
    effort: some filesystems refuse directory fsync — then the rename is
    only as durable as the OS makes it, which was the status quo)."""
    fd = os.open(dirpath or ".", os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write(path, data: bytes, *, plan=None) -> None:
    """Write ``data`` to ``path`` crash-atomically: tmp file in the same
    directory, fsync, ``os.replace``, parent-dir fsync.  A crash anywhere
    leaves either the old ``path`` bytes or the new ones — never a torn
    mix.  ``plan`` is an optional ``testing.FaultPlan`` whose
    ``crash_save`` injects the worst crash point (tmp durable, rename
    never issued)."""
    from repro.testing import faults

    path = str(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    faults.check_save(plan)
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


class DeltaWAL:
    """Append-only, CRC-framed, fsync'd log of delta inserts (DESIGN.md §7).

    One instance per snapshot path; ``append`` is called by
    ``SearchSession.add()`` *before* the rows are applied (write-ahead), so
    an acknowledged insert is always on disk.  ``frames()`` yields the
    valid frames of the log, truncating reads at (and warning about) the
    first torn/corrupt frame of each segment.  ``clear()`` empties the log
    atomically after a snapshot.

    With ``max_bytes`` > 0 the log is *segmented*: ``path`` itself is
    segment 0 and appends that find the active segment at or over the cap
    open the next numbered segment (``{path}.0001``, ``{path}.0002``, ...).
    Replay walks segments in order — the per-frame ``n_before`` guard keeps
    it idempotent regardless — so ``health()`` can bound WAL disk usage via
    :meth:`total_bytes` while no single file grows without limit between
    snapshots.
    """

    def __init__(self, path, *, max_bytes: int = 0):
        self.path = str(path)
        self.max_bytes = int(max_bytes or 0)

    # -- segments -------------------------------------------------------------
    def _segments(self) -> list[str]:
        """Existing segment paths in append/replay order: the base path
        (segment 0) first, then numbered rotations sorted numerically."""
        segs: list[str] = []
        if os.path.exists(self.path):
            segs.append(self.path)
        d = os.path.dirname(self.path) or "."
        base = os.path.basename(self.path) + "."
        try:
            names = os.listdir(d)
        except FileNotFoundError:
            names = []
        numbered = [(int(nm[len(base):]), os.path.join(d, nm))
                    for nm in names
                    if nm.startswith(base) and nm[len(base):].isdigit()]
        segs.extend(p for _, p in sorted(numbered))
        return segs

    def _active_path(self) -> str:
        """The segment the next append lands in (rotating past a full
        one when ``max_bytes`` caps segment size)."""
        segs = self._segments()
        if not segs:
            return self.path
        last = segs[-1]
        if self.max_bytes > 0 and os.path.getsize(last) >= self.max_bytes:
            nxt = 1 if last == self.path else int(last.rsplit(".", 1)[1]) + 1
            return f"{self.path}.{nxt:04d}"
        return last

    # -- write ----------------------------------------------------------------
    def append(self, rows: np.ndarray, n_before: int, *, plan=None) -> None:
        """Frame ``rows`` (inserted when the corpus held ``n_before``
        vectors) and fsync it.  ``plan`` is an optional
        ``testing.FaultPlan`` whose ``torn_frame_keep`` simulates power
        loss mid-write: the frame's byte prefix is written and
        ``SimulatedCrash`` raised, so the caller never acknowledges."""
        from repro.testing import faults

        buf = io.BytesIO()
        np.savez(buf, n_before=np.int64(n_before),
                 rows=np.ascontiguousarray(rows, np.float32))
        payload = buf.getvalue()
        frame = (_WAL_MAGIC + _WAL_HEADER.pack(len(payload),
                                               zlib.crc32(payload)) + payload)
        out, crash = faults.torn_frame(plan, frame)
        target = self._active_path()
        with open(target, "ab") as f:
            f.write(out)
            f.flush()
            os.fsync(f.fileno())
        if crash:
            raise faults.SimulatedCrash(
                f"injected crash mid-WAL-frame: wrote {len(out)} of "
                f"{len(frame)} bytes to {target}")

    # -- read -----------------------------------------------------------------
    def _scan(self, path=None) -> tuple[list[tuple[int, np.ndarray]],
                                        int, int]:
        """Parse one segment (default: the base): (valid frames, bytes of
        valid prefix, file size).  A torn or corrupt tail warns — never a
        crash — because a torn frame was by construction never
        acknowledged."""
        path = self.path if path is None else str(path)
        try:
            with open(path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return [], 0, 0
        out: list[tuple[int, np.ndarray]] = []
        off, hdr = 0, _WAL_HEADER.size
        while off < len(data):
            head = data[off:off + 4 + hdr]
            if len(head) < 4 + hdr or head[:4] != _WAL_MAGIC:
                warnings.warn(
                    f"delta WAL {path}: torn/garbled frame header at "
                    f"byte {off}; dropping the unacknowledged tail "
                    f"({len(data) - off} bytes)", stacklevel=3)
                break
            ln, crc = _WAL_HEADER.unpack(head[4:])
            payload = data[off + 4 + hdr: off + 4 + hdr + ln]
            if len(payload) < ln or zlib.crc32(payload) != crc:
                warnings.warn(
                    f"delta WAL {path}: frame at byte {off} fails "
                    f"length/CRC (torn write); dropping the unacknowledged "
                    f"tail ({len(data) - off} bytes)", stacklevel=3)
                break
            with np.load(io.BytesIO(payload)) as z:
                out.append((int(z["n_before"]), np.asarray(z["rows"],
                                                          np.float32)))
            off += 4 + hdr + ln
        return out, off, len(data)

    def frames(self) -> list[tuple[int, np.ndarray]]:
        """The valid ``(n_before, rows)`` frames across all segments, in
        log order (each segment's torn tail dropped with a warning)."""
        out: list[tuple[int, np.ndarray]] = []
        for seg in self._segments() or [self.path]:
            out.extend(self._scan(seg)[0])
        return out

    def total_bytes(self) -> int:
        """On-disk size of the log, summed over every segment (surfaced in
        ``SearchService.health()`` as ``wal_bytes``)."""
        return sum(os.path.getsize(seg) for seg in self._segments())

    def clear(self) -> None:
        """Empty the log (a fresh snapshot supersedes every frame):
        numbered segments are unlinked, the base segment is emptied via the
        same tmp + ``os.replace`` + dir-fsync dance as the snapshot — a
        crash mid-clear leaves either the old log (harmless: replay is
        idempotent) or the empty one, never a torn file."""
        for seg in self._segments():
            if seg != self.path:
                os.remove(seg)
        _atomic_write(self.path, b"")

    def replay(self, session) -> int:
        """Apply, segment by segment in order, every frame not already
        reflected in ``session`` (frames with ``n_before < session.n`` are
        skipped — that is what makes a double replay a no-op), then
        truncate each segment's torn tail so the *next* ``append`` lands on
        a frame boundary instead of behind garbage.  Returns rows
        applied."""
        frames: list[tuple[int, np.ndarray]] = []
        for seg in self._segments() or [self.path]:
            seg_frames, valid_end, size = self._scan(seg)
            if valid_end < size:       # torn tail: cut the segment back to
                with open(seg, "rb+") as f:   # the last acknowledged frame
                    f.truncate(valid_end)
                    f.flush()
                    os.fsync(f.fileno())
            frames.extend(seg_frames)
        applied = 0
        for n_before, rows in frames:
            if n_before < session.n:
                continue               # snapshot or earlier replay has it
            if not np.isfinite(rows).all():
                # a frame that passed CRC but holds NaN/Inf rows was logged
                # by a writer without add()'s finiteness gate (or corrupted
                # in a CRC-colliding way): applying it would poison every
                # distance against those rows, so skip it loudly instead
                warnings.warn(
                    f"delta WAL {self.path}: frame logged at n_before="
                    f"{n_before} contains non-finite rows "
                    f"({rows.shape[0]} rows); skipping it — re-add the "
                    "data through SearchSession.add(), which validates",
                    stacklevel=2)
                continue
            session._apply_add(rows)
            applied += rows.shape[0]
        return applied


def _wal_for(path, policy) -> DeltaWAL:
    """The WAL armed for snapshot ``path``, honoring the policy's
    ``wal_max_bytes`` rotation knob (0/absent = single segment)."""
    return DeltaWAL(wal_path(path),
                    max_bytes=getattr(policy, "wal_max_bytes", 0) or 0)


def save_session(session, path) -> None:
    """Pickle a session's fitted method state, index, and policy — with a
    crc32 integrity trailer so a later load can prove the bytes are the
    ones written — then arm the delta WAL at ``path + ".wal"`` (clearing
    any previous log; this snapshot includes everything) so later ``add()``
    calls are crash-safe.

    The write is crash-atomic (tmp + ``os.replace`` + dir fsync): until
    the rename lands, the previous snapshot AND its un-cleared WAL are
    intact on disk, so a crash mid-save (``FaultPlan(crash_save=...)``)
    loses nothing — the old state reloads, delta frames and all."""
    payload = {
        "version": FORMAT_VERSION,
        "method_name": session.method.name,
        "method_params": session.method.params,
        "method_state": session.method.state,
        "index_kind": session.index_kind,
        "index": session.index,
        "policy": session.policy,
        "backend": session.backend.name,
    }
    from repro.testing import faults

    body = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    _atomic_write(
        path, body + _SNAP_MAGIC
        + _SNAP_TRAILER.pack(len(body), zlib.crc32(body)),
        plan=faults.active(session.policy))
    session.wal = _wal_for(path, session.policy)
    session.wal.clear()


def load_session(path, *, backend: str | None = None, mesh=None):
    """Rebuild a ``SearchSession`` from :func:`save_session` output, then
    replay its delta WAL (inserts since the snapshot).  Raises
    :class:`IndexLoadError` on any unreadable/unsupported snapshot."""
    from repro.api.session import SearchSession
    from repro.core.methods import make_method

    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        raise IndexLoadError(path, "file does not exist") from None
    # verify the integrity trailer BEFORE unpickling: unpickling corrupt
    # bytes can fail arbitrarily late (or succeed with silently wrong
    # arrays), while the crc32 check is cheap and total
    tlen = len(_SNAP_MAGIC) + _SNAP_TRAILER.size
    if len(data) < tlen or \
            data[-tlen:-_SNAP_TRAILER.size] != _SNAP_MAGIC:
        raise IndexLoadError(
            path, "missing integrity trailer (truncated snapshot, or not "
            "written by save_session)")
    ln, crc = _SNAP_TRAILER.unpack(data[-_SNAP_TRAILER.size:])
    body = data[:-tlen]
    if ln != len(body) or zlib.crc32(body) != crc:
        raise IndexLoadError(
            path, f"snapshot checksum mismatch (trailer says {ln} payload "
            f"bytes, crc32 {crc:#010x}; file holds {len(body)} bytes, "
            f"crc32 {zlib.crc32(body):#010x}) — the snapshot was corrupted "
            "after it was written; restore from a good copy")
    try:
        payload = pickle.loads(body)
    except (pickle.UnpicklingError, EOFError, AttributeError,
            ImportError, IndexError) as exc:
        raise IndexLoadError(
            path, f"not a readable session snapshot (foreign file? "
            f"unpickling failed with {type(exc).__name__}: {exc})",
        ) from exc
    if not isinstance(payload, dict) or "method_name" not in payload:
        raise IndexLoadError(
            path, "pickle payload is not a session snapshot")
    if payload.get("version") != FORMAT_VERSION:
        raise IndexLoadError(
            path, f"snapshot format version {payload.get('version')!r} is "
            f"not supported (this build reads version {FORMAT_VERSION}; "
            "re-save with the matching release)")
    m = make_method(payload["method_name"], **payload["method_params"])
    m.state = payload["method_state"]          # fitted state, no refit
    sess = SearchSession(m, payload["index_kind"], payload["index"],
                         backend or payload["backend"], payload["policy"],
                         mesh=mesh)
    sess.wal = _wal_for(path, sess.policy)
    sess.wal.replay(sess)
    return sess
