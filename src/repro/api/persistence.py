"""Save/load of fitted sessions.

The fitted state is plain numpy (method state dicts, index arrays), so a
single pickle payload round-trips everything the online path needs — fit
once, serve anywhere.  Device arrays are NOT persisted; the jax backend
re-materializes them lazily from ``device_state()`` on first search.
"""
from __future__ import annotations

import pickle

FORMAT_VERSION = 1


def save_session(session, path) -> None:
    """Pickle a session's fitted method state, index, and policy."""
    payload = {
        "version": FORMAT_VERSION,
        "method_name": session.method.name,
        "method_params": session.method.params,
        "method_state": session.method.state,
        "index_kind": session.index_kind,
        "index": session.index,
        "policy": session.policy,
        "backend": session.backend.name,
    }
    with open(path, "wb") as f:
        pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)


def load_session(path, *, backend: str | None = None, mesh=None):
    """Rebuild a ``SearchSession`` from :func:`save_session` output."""
    from repro.api.session import SearchSession
    from repro.core.methods import make_method

    with open(path, "rb") as f:
        payload = pickle.load(f)
    if payload.get("version") != FORMAT_VERSION:
        raise ValueError(f"unsupported session format {payload.get('version')!r}")
    m = make_method(payload["method_name"], **payload["method_params"])
    m.state = payload["method_state"]          # fitted state, no refit
    return SearchSession(m, payload["index_kind"], payload["index"],
                         backend or payload["backend"], payload["policy"],
                         mesh=mesh)
