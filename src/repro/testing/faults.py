"""Fault-injection plan for the serving robustness layer (DESIGN.md §7).

The serving stack has three failure modes the paper's instability result
implies in production: a pathological block that blows the latency budget,
a device step that dies mid-batch, and a crash that tears the last delta-WAL
frame.  This module makes all three *injectable* so the chaos tests
(tests/test_robustness.py, tests/test_wal.py) and the robustness benchmark
(benchmarks/bench_robustness.py) can drive them deterministically:

    with faults.inject(slow_block_s=0.01):
        sess.search(Q, 10, deadline_s=0.005)     # deadline now fires

Three injection routes, in precedence order:

1. ``SchedulePolicy(faults=FaultPlan(...))`` — scoped to one session; the
   backends consult their policy's plan first.
2. ``faults.inject(...)`` — a context manager that installs a process-global
   plan (used by tests).
3. ``REPRO_FAULTS="slow_block_s=0.01,fail_search_after=3"`` — environment
   variable, parsed once, for injecting into a process you don't own (the CI
   smoke step).

Hook points (all no-ops when no plan is active):

``sleep_block(plan)``
    called by both engines between row-block groups — simulates a slow
    block/host ("Bang for the Buck": identical workloads vary widely across
    cloud instances), which is what makes deadline expiry testable.
``check_search(plan)``
    called at backend ``search()`` entry — raises :class:`FaultError` on the
    N-th call (0-indexed count AFTER which the next call fails), simulating
    a device-step exception the serving loop must absorb.
``torn_frame(plan, buf)``
    consulted by the delta WAL's ``append`` — returns the byte prefix to
    actually write and whether to simulate a crash (the writer then raises
    :class:`SimulatedCrash` after the partial write, modeling power loss
    mid-frame).  Consumed once per armed plan.
``drift_override(plan, score)`` / ``audit_override(plan, recall)``
    consulted by the guardrail layer (core.guardrails, DESIGN.md §9) —
    replace the sentinel's measured drift score / the audit-or-canary
    sample recall, so breaker trips and audit divergence are injectable
    deterministically (the guardrail state-machine edge tests).
``check_replica(plan, idx)`` / ``replica_delay(plan, idx)``
    consulted by the replicated serving tier (serving.replica, DESIGN.md
    §10) per replica dispatch — kill replica ``dead_replica`` (immediately,
    or after its ``fail_replica_after``-th dispatch) and report an extra
    simulated stall for replica ``slow_replica`` (charged to the virtual
    timeline, never slept: failover replays stay fast and replay-exact).
``check_save(plan)``
    consulted by ``save_session`` between the tmp-file write and the atomic
    ``os.replace`` — raises :class:`SimulatedCrash` on the armed save,
    modeling power loss mid-snapshot (the old snapshot must survive).

``FaultPlan`` is a frozen dataclass (hashable, safe inside the frozen
``SchedulePolicy``); mutable runtime counters live module-side and reset
whenever a new plan is installed via :func:`inject` / :func:`install`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time


class FaultError(RuntimeError):
    """Injected device-step failure (the harness's stand-in for an XLA/
    driver error escaping a jitted search call)."""


class SimulatedCrash(RuntimeError):
    """Injected process death mid-WAL-write: the frame on disk is torn and
    the caller never gets an acknowledgement."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What to inject.  All fields default to "no fault".

    ``slow_block_s``        sleep this long per scanned block group.
    ``fail_search_after``   raise ``FaultError`` on search call number N
                            (0-based; -1 = never).
    ``torn_frame_keep``     on the next WAL frame write, keep only this
                            fraction of the frame's bytes (0 <= f < 1) and
                            raise ``SimulatedCrash``; -1.0 = never.
    ``drift_score``         override the guardrail sentinel's raw batch
                            drift score with this value (0 <= s <= 1;
                            -1.0 = no override) — makes breaker trips
                            deterministic regardless of query content.
    ``audit_recall``        override the guardrail audit/canary sampled
                            recall (0 <= r <= 1; -1.0 = no override) —
                            injects audit divergence without needing a
                            screen that actually loses neighbors.
    ``dead_replica``        replica index whose dispatches raise
                            ``FaultError`` (-1 = none).  Fails immediately
                            unless ``fail_replica_after`` delays the onset.
    ``fail_replica_after``  the dead replica serves this many dispatches
                            first, then every later one fails (-1 = fail
                            from the first dispatch) — the mid-run kill.
    ``slow_replica``        replica index reporting an extra simulated
                            stall per dispatch (-1 = none).
    ``slow_replica_s``      the stall, in (virtual) seconds, charged to
                            ``slow_replica``'s dispatch wall.
    ``crash_save``          raise ``SimulatedCrash`` on save call number N
                            (0-based), after the tmp write but before the
                            atomic rename (-1 = never).
    """

    slow_block_s: float = 0.0
    fail_search_after: int = -1
    torn_frame_keep: float = -1.0
    drift_score: float = -1.0
    audit_recall: float = -1.0
    dead_replica: int = -1
    fail_replica_after: int = -1
    slow_replica: int = -1
    slow_replica_s: float = 0.0
    crash_save: int = -1


# module-side runtime state: the active global plan and mutable counters
# (keyed by plan identity so a SchedulePolicy-scoped plan gets its own count)
_GLOBAL: FaultPlan | None = None
_COUNTERS: dict = {}


def _env_plan() -> FaultPlan | None:
    spec = os.environ.get("REPRO_FAULTS", "").strip()
    if not spec:
        return None
    kw: dict = {}
    for item in spec.split(","):
        key, _, val = item.partition("=")
        key = key.strip()
        if key not in FaultPlan.__dataclass_fields__:
            raise ValueError(f"REPRO_FAULTS: unknown field {key!r}")
        typ = FaultPlan.__dataclass_fields__[key].type
        kw[key] = int(val) if "int" in typ else float(val)
    return FaultPlan(**kw)


def active(policy=None) -> FaultPlan | None:
    """The plan in effect: the policy-scoped plan, else the global/context
    plan, else the ``REPRO_FAULTS`` environment plan."""
    plan = getattr(policy, "faults", None)
    if plan is not None:
        return plan
    return _GLOBAL if _GLOBAL is not None else _env_plan()


def _reset(plan: FaultPlan) -> None:
    """Drop every counter keyed to ``plan``'s identity.  Must cover ALL
    counter kinds: a dataclass freed after its context exits can be
    re-allocated at the same ``id()``, and a stale key would make the new
    plan think it already fired."""
    _COUNTERS.pop(id(plan), None)
    _COUNTERS.pop(("torn", id(plan)), None)
    _COUNTERS.pop(("save", id(plan)), None)
    for key in [k for k in _COUNTERS
                if isinstance(k, tuple) and k[:2] == ("replica", id(plan))]:
        _COUNTERS.pop(key, None)


@contextlib.contextmanager
def inject(**kw):
    """Install a process-global :class:`FaultPlan` for the ``with`` body
    (counters reset on entry and the previous plan is restored on exit)."""
    global _GLOBAL
    prev = _GLOBAL
    plan = FaultPlan(**kw)
    _GLOBAL = plan
    _reset(plan)
    try:
        yield plan
    finally:
        _GLOBAL = prev
        _reset(plan)


def install(plan: FaultPlan | None) -> FaultPlan | None:
    """Swap the process-global plan *without* a context scope and return the
    previous one.  The failover benchmark uses this to kill and later revive
    a replica at chosen points of a Poisson replay — a ``with`` block can't
    straddle the replay loop.  Counters for the incoming plan are reset;
    callers restore the returned plan when done."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = plan
    if plan is not None:
        _reset(plan)
    return prev


def sleep_block(plan: FaultPlan | None) -> None:
    """Engine hook: stall one block group (no-op without a plan)."""
    if plan is not None and plan.slow_block_s > 0.0:
        time.sleep(plan.slow_block_s)


def check_search(plan: FaultPlan | None) -> None:
    """Backend hook: raise :class:`FaultError` when this call is the plan's
    ``fail_search_after``-th search (one failure, then the plan is spent)."""
    if plan is None or plan.fail_search_after < 0:
        return
    n = _COUNTERS.get(id(plan), 0)
    _COUNTERS[id(plan)] = n + 1
    if n == plan.fail_search_after:
        raise FaultError(
            f"injected device-step failure on search call {n} "
            f"(FaultPlan.fail_search_after={plan.fail_search_after})")


def drift_override(plan: FaultPlan | None, score: float) -> float:
    """Guardrail hook: replace the sentinel's measured raw drift score
    (``core.guardrails.Guardrail.run``) with the plan's, when armed."""
    if plan is None or plan.drift_score < 0.0:
        return score
    return float(plan.drift_score)


def audit_override(plan: FaultPlan | None, recall: float) -> float:
    """Guardrail hook: replace the measured audit/canary sample recall with
    the plan's, when armed — the audit-divergence injection route."""
    if plan is None or plan.audit_recall < 0.0:
        return recall
    return float(plan.audit_recall)


def check_replica(plan: FaultPlan | None, idx: int) -> None:
    """Replica-tier hook: raise :class:`FaultError` when replica ``idx`` is
    the plan's dead replica.  With ``fail_replica_after`` >= 0 the replica
    serves that many dispatches first (the mid-run kill); unlike
    ``check_search`` the failure is *persistent* — every dispatch after the
    onset fails until the plan is swapped out (revival)."""
    if plan is None or plan.dead_replica < 0 or idx != plan.dead_replica:
        return
    key = ("replica", id(plan), idx)
    n = _COUNTERS.get(key, 0)
    _COUNTERS[key] = n + 1
    if plan.fail_replica_after < 0 or n >= plan.fail_replica_after:
        raise FaultError(
            f"injected replica failure: replica {idx} dead "
            f"(dispatch {n}, FaultPlan.fail_replica_after="
            f"{plan.fail_replica_after})")


def replica_delay(plan: FaultPlan | None, idx: int) -> float:
    """Replica-tier hook: extra *simulated* seconds to charge to replica
    ``idx``'s dispatch wall (0.0 when not the slow replica).  Charged, not
    slept — the hedged-dispatch timeline stays virtual and replay-exact."""
    if plan is None or plan.slow_replica < 0 or idx != plan.slow_replica:
        return 0.0
    return float(max(plan.slow_replica_s, 0.0))


def check_save(plan: FaultPlan | None) -> None:
    """Persistence hook: raise :class:`SimulatedCrash` on the plan's
    ``crash_save``-th snapshot save, after the tmp file is written but
    before the atomic rename — the crash point the atomic-save test proves
    leaves the previous snapshot intact."""
    if plan is None or plan.crash_save < 0:
        return
    key = ("save", id(plan))
    n = _COUNTERS.get(key, 0)
    _COUNTERS[key] = n + 1
    if n == plan.crash_save:
        raise SimulatedCrash(
            f"injected crash on save {n} (FaultPlan.crash_save="
            f"{plan.crash_save}): tmp written, rename never happened")


def torn_frame(plan: FaultPlan | None, buf: bytes) -> tuple[bytes, bool]:
    """WAL hook: (bytes to actually write, crash_after_write).  Tears at
    most once per plan — later frames write whole again."""
    if plan is None or plan.torn_frame_keep < 0.0 \
            or _COUNTERS.get(("torn", id(plan))):
        return buf, False
    _COUNTERS[("torn", id(plan))] = True
    keep = max(0, min(len(buf) - 1, int(len(buf) * plan.torn_frame_keep)))
    return buf[:keep], True
