"""``repro.testing`` — fault-injection harness for chaos tests and the
robustness benchmark (DESIGN.md §7)."""
from repro.testing.faults import (FaultError, FaultPlan,  # noqa: F401
                                  SimulatedCrash, inject)
