import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script builds abstract inputs (ShapeDtypeStruct — no
allocation), attaches the production shardings, lowers the step function
against the production mesh, compiles it, and records
``memory_analysis()`` / ``cost_analysis()`` / parsed collective bytes into a
JSON artifact consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, applicable_shapes, get_arch
from repro.configs import sharding as SH
from repro.launch import roofline as RL
from repro.launch.mesh import dp_axes as mesh_dp_axes, make_production_mesh
from repro.models import build_model
from repro.train.train_step import init_state, make_train_step


def _sds(shape, dtype, mesh=None, spec=None):
    sh = NamedSharding(mesh, spec) if mesh is not None and spec is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _attach(tree_sds, mesh, specs):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        tree_sds, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_sds(cfg, shape, mesh, dp):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.ShapeDtypeStruct((B, min(S, 1024), cfg.d_model),
                                                   jnp.float32)
    if cfg.family == "vlm" and cfg.prefix_len:
        batch["patches"] = jax.ShapeDtypeStruct((B, cfg.prefix_len, cfg.d_model),
                                                jnp.float32)
    return _attach(batch, mesh, SH.batch_specs(batch, mesh, dp=dp))


def _bf16(tree_sds):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        tree_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lower_cell(arch: str, shape_name: str, mesh, *, fsdp=None,
               moment_dtype=jnp.float32, remat="block", pad_heads=False,
               attn_blocks=None, retrieval_overrides=None):
    """Returns (lowered, chips, model_flops)."""
    dp = mesh_dp_axes(mesh)
    fsdp = fsdp if fsdp is not None else dp
    chips = int(np.prod(list(mesh.shape.values())))

    if arch == "dco-retrieval":
        return _lower_retrieval(shape_name, mesh, chips,
                                overrides=retrieval_overrides)

    cfg = get_arch(arch)
    if attn_blocks:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, attn_block_q=attn_blocks[0],
                          attn_block_kv=attn_blocks[1])
    if pad_heads and cfg.n_heads:
        # Megatron-style: pad query heads to a TP-divisible count so GSPMD
        # never contraction-shards attention (EXPERIMENTS.md §Perf cell B).
        import dataclasses as _dc
        tp = mesh.shape["model"]
        if cfg.n_heads % tp:
            cfg = _dc.replace(cfg, n_heads=((cfg.n_heads + tp - 1) // tp) * tp)
    shape = SHAPES[shape_name]
    api = build_model(cfg, mesh=mesh, dp_axes=dp, remat=remat)
    mf = RL.model_flops_estimate(cfg, shape)

    if shape.kind == "train":
        state_sds = jax.eval_shape(
            lambda: init_state(api, jax.random.PRNGKey(0),
                               moment_dtype=moment_dtype))
        pspecs = SH.param_specs(state_sds.params, mesh, fsdp=fsdp)
        state_sds = jax.tree_util.tree_map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            (state_sds.params, state_sds.opt["m"], state_sds.opt["v"]),
            (pspecs, pspecs, pspecs),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        from repro.train.train_step import TrainState
        st = TrainState(state_sds[0],
                        {"m": state_sds[1], "v": state_sds[2],
                         "step": _sds((), jnp.int32, mesh, P())},
                        _sds((), jnp.int32, mesh, P()))
        batch = _batch_sds(cfg, shape, mesh, dp)
        step = make_train_step(api)
        return jax.jit(step).lower(st, batch), chips, mf

    params_sds = _bf16(jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0))))
    params_sds = _attach(params_sds, mesh,
                         SH.param_specs(params_sds, mesh, fsdp=fsdp))
    if shape.kind == "prefill":
        batch = _batch_sds(cfg, shape, mesh, dp)
        return jax.jit(api.prefill).lower(params_sds, batch), chips, mf

    # decode
    B, S = shape.global_batch, shape.seq_len
    cache_sds = jax.eval_shape(lambda: api.init_cache(B, S))
    cache_sds = _attach(cache_sds, mesh, SH.cache_specs(cache_sds, mesh, dp=dp))
    token = _sds((B,), jnp.int32, mesh, P(SH._maybe(mesh, B, dp)))
    cur_len = _sds((B,), jnp.int32, mesh, P(SH._maybe(mesh, B, dp)))
    return jax.jit(api.decode_step).lower(params_sds, cache_sds, token,
                                          cur_len), chips, mf


def _lower_retrieval(shape_name, mesh, chips, overrides=None):
    from repro.configs.dco_bench import CONFIG as rc
    from repro.core.jax_engine import DcoEngineConfig, make_distributed_topk
    ov = overrides or {}
    axes = tuple(mesh.axis_names)
    n_per = (rc.n_total + chips - 1) // chips
    n = n_per * chips
    cfg = DcoEngineConfig(kind=rc.kind, d1=ov.get("d1", rc.d1), k=rc.k,
                          capacity=ov.get("capacity", rc.capacity),
                          query_chunk=ov.get("query_chunk", 8))
    fn = make_distributed_topk(mesh, cfg, shard_axes=axes)
    spec = P(axes)
    sdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        ov.get("stage1_dtype", "float32")]
    tdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        ov.get("tail_dtype", "float32")]
    args = (
        _sds((n, cfg.d1), sdt, mesh, spec),
        _sds((n, rc.dim - cfg.d1), tdt, mesh, spec),
        _sds((n,), jnp.float32, mesh, spec),
        _sds((n,), jnp.float32, mesh, spec),
        _sds((rc.query_batch, cfg.d1), sdt, mesh, P()),
        _sds((rc.query_batch, rc.dim - cfg.d1), tdt, mesh, P()),
        {},                                  # q_extra (per-query rule scalars)
    )
    # model "flops": stage-1 exact cost (the useful work of the scan)
    mf = 2.0 * rc.query_batch * rc.n_total * rc.d1
    return jax.jit(fn).lower(*args), chips, mf


def run_cell(arch, shape_name, mesh_kind, out_dir, tag="", **kw):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "tag": tag, "options": str(kw)}
    sfx = f"__{tag}" if tag else ""
    try:
        lowered, chips, mf = lower_cell(arch, shape_name, mesh, **kw)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec.update(RL.analyze(compiled, chips=chips, model_flops=mf))
        rec.update({"lower_s": t1 - t0, "compile_s": t2 - t1, "ok": True})
        try:                                  # save HLO for offline re-analysis
            import zstandard
            os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
            hp = os.path.join(out_dir, "hlo",
                              f"{mesh_kind}__{arch}__{shape_name}{sfx}.hlo.zst")
            with open(hp, "wb") as f:
                f.write(zstandard.ZstdCompressor(level=6).compress(
                    compiled.as_text().encode()))
        except Exception:
            pass
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{mesh_kind}__{arch}__{shape_name}{sfx}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = "OK" if rec.get("ok") else "FAIL"
    dom = rec.get("dominant", "-")
    print(f"[{status}] {mesh_kind:8s} {arch:22s} {shape_name:12s} "
          f"dominant={dom} t={time.time()-t0:.1f}s", flush=True)
    return rec


def all_cells():
    cells = []
    for arch in ARCH_NAMES:
        cfg = get_arch(arch)
        for s in applicable_shapes(cfg):
            cells.append((arch, s))
    cells.append(("dco-retrieval", "serve"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--pad-heads", action="store_true")
    ap.add_argument("--fsdp-data-only", action="store_true",
                    help="multipod: FSDP within pod only (pod axis pure DP)")
    ap.add_argument("--moment-bf16", action="store_true")
    ap.add_argument("--attn-blocks", default="",
                    help="block_q,block_kv override for blockwise attention")
    ap.add_argument("--retr", default="",
                    help="retrieval overrides k=v,... (stage1_dtype, tail_dtype, d1, capacity)")
    ap.add_argument("--tag", default="", help="suffix for artifact filenames")
    args = ap.parse_args()
    if args.list:
        for a, s in all_cells():
            print(a, s)
        return
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    kw = {}
    if args.pad_heads:
        kw["pad_heads"] = True
    if args.fsdp_data_only:
        kw["fsdp"] = ("data",)
    if args.moment_bf16:
        kw["moment_dtype"] = jnp.bfloat16
    if args.attn_blocks:
        kw["attn_blocks"] = tuple(int(x) for x in args.attn_blocks.split(","))
    if args.retr:
        ov = {}
        for kv2 in args.retr.split(","):
            k2, v2 = kv2.split("=")
            ov[k2] = int(v2) if v2.isdigit() else v2
        kw["retrieval_overrides"] = ov
    for mk in meshes:
        for arch, shape in cells:
            run_cell(arch, shape, mk, args.out, tag=args.tag, **kw)


if __name__ == "__main__":
    main()
