"""Re-run the roofline analysis over saved HLO artifacts (no recompiling)."""
import glob
import json
import os
import sys

import zstandard

from repro.launch import roofline as RL
from repro.launch.hlo_cost import analyze_hlo


def main(out_dir="artifacts/dryrun"):
    for jpath in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(jpath))
        if not rec.get("ok"):
            continue
        tag = rec.get("tag", "")
        sfx = f"__{tag}" if tag else ""
        hpath = os.path.join(out_dir, "hlo",
                             f"{rec['mesh']}__{rec['arch']}__{rec['shape']}{sfx}.hlo.zst")
        if not os.path.exists(hpath):
            continue
        hlo = zstandard.ZstdDecompressor().decompress(
            open(hpath, "rb").read(), max_output_size=2 ** 31).decode()
        tot = analyze_hlo(hlo)
        chips = rec["chips"]
        terms = {
            "compute_s": tot["flops"] / RL.PEAK_FLOPS,
            "memory_s": tot["bytes"] / RL.HBM_BW,
            "collective_s": tot["collective_bytes"] / RL.ICI_BW,
        }
        rec.update({
            "hlo_flops_per_device": tot["flops"],
            "hlo_bytes_per_device": tot["bytes"],
            "hlo_bytes_upper_per_device": tot["bytes_upper"],
            "collective_bytes_per_device": tot["collective_bytes"],
            "collectives": tot["collectives"],
            "terms_s": terms,
            "dominant": max(terms, key=terms.get),
        })
        if rec.get("model_flops"):
            rec["useful_ratio"] = rec["model_flops"] / (tot["flops"] * chips)
        json.dump(rec, open(jpath, "w"), indent=1, default=str)
        print(f"reanalyzed {os.path.basename(jpath)}: dominant={rec['dominant']}")


if __name__ == "__main__":
    main(*sys.argv[1:])
