"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scanned-layer models by ~n_layers x.  This module parses the
post-SPMD HLO text, builds the computation call graph, and accumulates

    flops  — dot ops: 2 * |result| * K  (+1 flop/elem for top-level arith)
    bytes  — per top-level op: |result| + sum |operands|   (fusion-aware:
             fused subcomputations are invisible, the fusion op's operands /
             result ARE the HBM traffic — XLA's own accounting model)
    collective bytes — result sizes of all-reduce / all-gather /
             reduce-scatter / all-to-all / collective-permute

weighted by ``known_trip_count`` of every enclosing while loop.  All numbers
are PER DEVICE (the module is already SPMD-partitioned).

Byte model (the "fused"/primary estimate): a TPU pipeline keeps loop-body
intermediates in VMEM, so an op is charged HBM traffic only for
  * operands produced by parameter / get-tuple-element (weights, loop
    carries, entry args) — these stream from HBM each iteration,
  * operands or results larger than VMEM_CAP (64 MB) — too big to stay
    resident (e.g. the (T, d_ff) MLP intermediate),
while small in-body intermediates (e.g. a 33 MB flash-attention score tile)
are free.  ``bytes_upper`` keeps the charge-everything bound for reference.
"""
from __future__ import annotations

import re
from collections import defaultdict

VMEM_CAP = 64 * 2**20

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|token)"
    r"\[([0-9,]*)\]")

_OPLINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"([\w\-]+)\((.*)$")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose top-level appearance implies real HBM traffic
_ZERO_BYTE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "while", "iota", "after-all", "partition-id",
                  "replica-id", "custom-call", "conditional", "call",
                  "rng-bit-generator"}

_ARITH_FLOP_OPS = {"add", "subtract", "multiply", "divide", "negate", "select",
                   "maximum", "minimum", "compare", "exponential", "log",
                   "rsqrt", "sqrt", "tanh", "clamp", "power", "and", "or",
                   "convert", "reduce", "reduce-window"}

# ops a TPU compile would fuse into neighbours (CPU leaves them top-level):
# charged 0 bytes in the "fused" estimate, full bytes in the "upper" bound.
_FUSABLE = {"add", "subtract", "multiply", "divide", "negate", "select",
            "maximum", "minimum", "compare", "exponential", "exponential-minus-one",
            "log", "log-plus-one", "rsqrt", "sqrt", "cbrt", "tanh", "logistic",
            "clamp", "power", "and", "or", "not", "xor", "abs", "sign",
            "floor", "ceil", "round-nearest-afz", "round-nearest-even",
            "convert", "broadcast", "transpose", "reshape", "slice", "pad",
            "reverse", "concatenate", "is-finite", "shift-left",
            "shift-right-logical", "shift-right-arithmetic", "rem", "atan2",
            "expm1", "log1p", "cosine", "sine", "real", "imag"}


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(text: str) -> int:
    return sum(_nelems(d) * _DT_BYTES[t] for t, d in _SHAPE_RE.findall(text))


def _shape_elems(text: str) -> int:
    return sum(_nelems(d) for d, in [(d,) for _, d in _SHAPE_RE.findall(text)])


def _first_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",")] if m.group(2) else []


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = {}                  # name -> list of parsed op dicts
        self.entry = None
        self._parse(hlo_text)
        self._memo = {}

    # ------------------------------------------------------------------
    def _parse(self, text):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{") and \
                    (line.startswith("%") or line.startswith("ENTRY")):
                head = line.split()[1] if line.startswith("ENTRY") else line.split()[0]
                cur = head.lstrip("%").split("(")[0].strip()
                self.comps[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None or "=" not in line:
                continue
            m = _OPLINE.match(line)
            if not m:
                continue
            name, shape_s, opcode, rest = m.groups()
            op = {"name": name, "shape": shape_s.strip(), "opcode": opcode,
                  "rest": rest}
            if opcode == "dot":
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                op["lhs_cdims"] = [int(x) for x in mm.group(1).split(",")] if mm and mm.group(1) else []
                op["operands"] = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
            elif opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                mc = re.search(r"condition=%?([\w.\-]+)", rest)
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
                op["body"] = mb.group(1) if mb else None
                op["cond"] = mc.group(1) if mc else None
                op["trip"] = int(mt.group(1)) if mt else 1
                op["trip_known"] = bool(mt)
            elif opcode in ("fusion", "call", "reduce", "reduce-window", "sort",
                            "map", "scatter", "select-and-scatter"):
                mm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rest)
                op["calls"] = mm.group(1) if mm else None
                op["operands"] = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
            else:
                op["operands"] = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
            self.comps[cur].append(op)

    # ------------------------------------------------------------------
    def _comp_cost(self, comp_name):
        if comp_name in self._memo:
            return self._memo[comp_name]
        flops = bytes_ = bytes_fused = 0.0
        coll = defaultdict(float)
        unknown_trips = 0
        shapes = {}
        producer = {}
        ops = self.comps.get(comp_name, [])
        for op in ops:
            shapes[op["name"]] = op["shape"]
            producer[op["name"]] = op["opcode"]

        _HBM_SRC = {"parameter", "get-tuple-element", "constant"}

        def _charge(op):
            """HBM bytes for this op under the VMEM-residency model."""
            oc = op["opcode"]
            rb = _shape_bytes(op["shape"])
            # slicing reads only the window — and only when the SOURCE is in
            # HBM (big, or a loop carry/parameter); slicing a VMEM-resident
            # tensor is free
            def _src_in_hbm():
                return any(
                    _shape_bytes(shapes.get(o, "")) > VMEM_CAP
                    or (producer.get(o, "parameter") in _HBM_SRC
                        and _shape_bytes(shapes.get(o, "")) > VMEM_CAP)
                    for o in op.get("operands", []))
            if oc in ("dynamic-slice", "slice", "gather"):
                return rb if _src_in_hbm() else 0
            if oc in ("dynamic-update-slice", "scatter"):
                upd = op.get("operands", [None, None])[1:2]
                ub = _shape_bytes(shapes.get(upd[0], "")) if upd else rb
                return (2 * min(ub, rb)) if (rb > VMEM_CAP) else 0
            total = 0
            for o in op.get("operands", []):
                b = _shape_bytes(shapes.get(o, ""))
                if producer.get(o, "parameter") in _HBM_SRC or b > VMEM_CAP:
                    total += b
            if rb > VMEM_CAP:
                total += rb
            return total

        for op in ops:
            oc = op["opcode"]
            if oc == "while":
                sub_f = [0.0, 0.0, 0.0]
                sub_c, sub_u = defaultdict(float), 0
                for sub in (op["body"], op["cond"]):
                    if sub and sub in self.comps:
                        f, b, bf, c, u = self._comp_cost(sub)
                        sub_f[0] += f
                        sub_f[1] += b
                        sub_f[2] += bf
                        for k, v in c.items():
                            sub_c[k] += v
                        sub_u += u
                t = op["trip"]
                flops += t * sub_f[0]
                bytes_ += t * sub_f[1]
                bytes_fused += t * sub_f[2]
                for k, v in sub_c.items():
                    coll[k] += t * v
                unknown_trips += sub_u + (0 if op["trip_known"] else 1)
                continue
            if oc == "dot":
                res = _shape_elems(op["shape"])
                k = 1
                lhs = op.get("operands", [None])[0]
                lhs_shape = shapes.get(lhs, "")
                dims = _first_dims(lhs_shape)
                for ci in op.get("lhs_cdims", []):
                    if ci < len(dims):
                        k *= dims[ci]
                flops += 2.0 * res * k
                bytes_ += _shape_bytes(op["shape"]) + sum(
                    _shape_bytes(shapes.get(o, "")) for o in op.get("operands", []))
                bytes_fused += _charge(op)
                continue
            if oc in ("fusion", "call"):
                sub = op.get("calls")
                sub_ops = self.comps.get(sub, []) if sub else []
                if sub and sub in self.comps:
                    f, _b, _bf, c, u = self._comp_cost(sub)  # flops only:
                    flops += f                               # traffic is the
                    unknown_trips += u                       # fusion op's
                    for k, v in c.items():
                        coll[k] += v
                bytes_ += _shape_bytes(op["shape"]) + sum(
                    _shape_bytes(shapes.get(o, "")) for o in op.get("operands", []))
                kinds = {o2["opcode"] for o2 in sub_ops}
                rb = _shape_bytes(op["shape"])
                op_bytes = [_shape_bytes(shapes.get(o, ""))
                            for o in op.get("operands", [])]
                has_big_src = any(b > VMEM_CAP for b in op_bytes)
                if kinds & {"dynamic-update-slice", "scatter"} and rb > VMEM_CAP:
                    # window write into an HBM buffer: 2x the (small) update
                    # operands; the big buffer passes through untouched
                    bytes_fused += 2 * sum(b for b in op_bytes if b <= VMEM_CAP)
                elif kinds & {"dynamic-slice", "slice", "gather"} and has_big_src:
                    # window read out of an HBM buffer: result + small operands
                    bytes_fused += rb + sum(
                        b for b in op_bytes if b <= min(VMEM_CAP, 4 * max(rb, 1)))
                else:
                    bytes_fused += _charge(op)
                continue
            for kind in _COLL_KINDS:
                if oc == kind or oc == kind + "-start":
                    b = _shape_bytes(op["shape"])
                    coll[kind] += b
                    bytes_ += b
                    bytes_fused += b
                    break
            else:
                if oc in _ZERO_BYTE_OPS or oc.endswith("-done"):
                    continue
                if oc in _ARITH_FLOP_OPS:
                    flops += _shape_elems(op["shape"])
                bytes_ += _shape_bytes(op["shape"]) + sum(
                    _shape_bytes(shapes.get(o, "")) for o in op.get("operands", []))
                bytes_fused += _charge(op)
        out = (flops, bytes_, bytes_fused, coll, unknown_trips)
        self._memo[comp_name] = out
        return out

    def totals(self):
        f, b, bf, c, u = self._comp_cost(self.entry)
        return {"flops": f, "bytes": bf, "bytes_upper": b,
                "collectives": dict(c),
                "collective_bytes": float(sum(c.values())),
                "unknown_trip_whiles": u}


def analyze_hlo(hlo_text: str) -> dict:
    return HloCost(hlo_text).totals()
