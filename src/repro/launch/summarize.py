"""Summarize dry-run artifacts into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt(v, digits=3):
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 0.001:
            return f"{v:.2e}"
        return f"{v:.{digits}g}"
    return str(v)


def table(rows, mesh):
    out = []
    out.append("| arch | shape | compute_s | memory_s | coll_s | dominant | "
               "peak GiB/dev | 6ND/HLO | MFU-bound | note |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        tag = r.get("tag", "")
        name = r["arch"] + (f" [{tag}]" if tag else "")
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | FAIL | - | - | "
                       f"{r.get('error','')[:60]} |")
            continue
        t = r["terms_s"]
        peak = r.get("memory", {}).get("temp_bytes")
        peak_g = f"{peak/2**30:.1f}" if peak else "-"
        ur = r.get("useful_ratio")
        # MFU implied by the dominant term under perfect overlap:
        # model_flops / (chips * peak_flops * max(terms))
        mfu = "-"
        if r.get("model_flops") and max(t.values()) > 0:
            from repro.launch.roofline import PEAK_FLOPS
            mfu = f"{r['model_flops'] / (r['chips'] * PEAK_FLOPS * max(t.values())):.1%}"
        out.append(
            f"| {name} | {r['shape']} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | {peak_g} | {fmt(ur)} | {mfu} | |")
    return "\n".join(out)


def skipped_cells():
    from repro.configs import ARCH_NAMES, get_arch, applicable_shapes
    out = []
    for a in ARCH_NAMES:
        cfg = get_arch(a)
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if s not in applicable_shapes(cfg):
                out.append((a, s, "pure full-attention arch: long_500k needs "
                            "sub-quadratic path (DESIGN.md §4)"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    rows = load(args.out)
    for mesh in ("pod", "multipod"):
        n_ok = sum(1 for r in rows if r.get("mesh") == mesh and r.get("ok"))
        print(f"\n### Mesh `{mesh}` ({n_ok} cells OK)\n")
        print(table(rows, mesh))
    print("\n### Skipped cells (documented)\n")
    for a, s, why in skipped_cells():
        print(f"- `{a}` x `{s}`: {why}")


if __name__ == "__main__":
    main()
