"""Serving launcher: continuous-batching demo over a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config, get_arch
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch) if args.full else smoke_config(args.arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, rng.integers(3, 10)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    eng = ServingEngine(api, slots=args.slots, max_len=128)
    t0 = time.perf_counter()
    out = eng.run(params, reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s)")
    for rid in sorted(out):
        print(f"  req {rid}: {out[rid]}")
    return out


if __name__ == "__main__":
    main()
