"""Training launcher: end-to-end driver (example-scale on CPU, production
shardings on a real mesh).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
      --smoke --ckpt-dir /tmp/ckpt

``--smoke`` swaps in the reduced config + tiny batch so the driver runs on
one CPU device; without it the full config is instantiated (requires the
production mesh / real accelerators).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, smoke_config
from repro.configs.base import RunShape
from repro.data import TokenPipeline, make_batch_fn
from repro.launch.mesh import dp_axes as mesh_dp_axes, make_host_mesh
from repro.models import build_model
from repro.train.fault import StepMonitor, run_resumable
from repro.train.train_step import init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance demo)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    shape = RunShape("cli", args.seq, args.batch, "train")
    api = build_model(cfg, remat="block")
    step_fn = jax.jit(make_train_step(api, microbatches=args.microbatches))
    state = init_state(api, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M steps={args.steps}")

    batch_fn_raw = make_batch_fn(cfg, shape)
    batch_fn = lambda s: {k: jnp.asarray(v) for k, v in batch_fn_raw(s).items()}

    if args.ckpt_dir:
        mon = StepMonitor()
        state, last = run_resumable(step_fn, state, batch_fn,
                                    steps=args.steps, ckpt_dir=args.ckpt_dir,
                                    ckpt_every=args.ckpt_every,
                                    monitor=mon, fail_at=args.fail_at)
        print(f"finished at step {last}; stragglers={len(mon.stragglers)}")
        return state

    pipe = TokenPipeline(batch_fn)
    t0 = time.perf_counter()
    for step, batch in pipe.iter(0, args.steps):
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step:4d} loss {loss:.4f} "
                  f"({time.perf_counter()-t0:.1f}s)")
    return state


if __name__ == "__main__":
    main()
