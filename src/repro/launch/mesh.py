"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and nothing else should.
"""
from __future__ import annotations

import jax


import numpy as np


def _mesh(shape, axes):
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax")
    devices = np.asarray(devs[:n]).reshape(shape)
    if hasattr(jax.sharding, "AxisType"):      # jax >= 0.5
        return jax.sharding.Mesh(
            devices, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — smoke tests."""
    return _mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes: ('pod','data') multi-pod, ('data',) single-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
