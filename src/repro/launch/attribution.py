"""Per-source-line attribution of HLO flops / bytes (the dry-run 'profiler').

With no real TPU, ``lowered.as_text()`` + the trip-count-weighted cost model
IS the profile (brief §Pallas hints).  This module joins each op's
``stack_frame_id`` with the FileNames/FunctionNames/FileLocations/StackFrames
tables that XLA emits at the top of the HLO dump, yielding
"file:function:line -> flops/bytes" — what a profiler's source view gives.
"""
from __future__ import annotations

import re
from collections import defaultdict

from repro.launch.hlo_cost import (HloCost, _first_dims, _shape_bytes,
                                   _shape_elems)

_ZERO = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "while", "iota"}


def parse_stack_tables(text: str) -> dict:
    """stack_frame_id -> 'file:function:line' (innermost frame)."""
    def table(name):
        m = re.search(rf"^{name}$", text, re.M)
        if not m:
            return {}
        out = {}
        for line in text[m.end():].splitlines()[1:]:
            mm = re.match(r"^(\d+) (.*)$", line)
            if not mm:
                break
            out[mm.group(1)] = mm.group(2)
        return out

    files = {k: v.strip('"').split("/")[-1] for k, v in table("FileNames").items()}
    funcs = {k: v.strip('"') for k, v in table("FunctionNames").items()}
    locs = {}
    for k, v in table("FileLocations").items():
        mm = re.search(r"file_name_id=(\d+) function_name_id=(\d+) line=(\d+)", v)
        if mm:
            locs[k] = (f"{files.get(mm.group(1), '?')}:"
                       f"{funcs.get(mm.group(2), '?')}:{mm.group(3)}")
    frames = {}
    for k, v in table("StackFrames").items():
        mm = re.search(r"file_location_id=(\d+)", v)
        if mm:
            frames[k] = locs.get(mm.group(1), "?")
    return frames


def attribute(hlo_text: str, top: int = 20) -> dict:
    """Returns {'flops': [(src, v), ...], 'bytes': [...]} trip-weighted."""
    frames = parse_stack_tables(hlo_text)
    hc = HloCost(hlo_text)
    mult = defaultdict(float)

    def visit(comp, m):
        mult[comp] += m
        for op in hc.comps.get(comp, []):
            if op["opcode"] == "while":
                for sub in (op.get("body"), op.get("cond")):
                    if sub:
                        visit(sub, m * op["trip"])
            elif op["opcode"] in ("fusion", "call") and op.get("calls"):
                visit(op["calls"], m)

    visit(hc.entry, 1.0)
    flops_by = defaultdict(float)
    bytes_by = defaultdict(float)
    for comp, ops in hc.comps.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        shapes = {o["name"]: o["shape"] for o in ops}
        for op in ops:
            mm = re.search(r"stack_frame_id=(\d+)", op.get("rest", ""))
            src = frames.get(mm.group(1), "untagged") if mm else "untagged"
            if op["opcode"] == "dot":
                res = _shape_elems(op["shape"])
                k = 1
                dims = _first_dims(shapes.get(op.get("operands", [None])[0], ""))
                for ci in op.get("lhs_cdims", []):
                    if ci < len(dims):
                        k *= dims[ci]
                flops_by[src] += m * 2.0 * res * k
            if op["opcode"] in _ZERO:
                continue
            b = _shape_bytes(op["shape"]) + sum(
                _shape_bytes(shapes.get(o, "")) for o in op.get("operands", []))
            bytes_by[src] += m * b
    rank = lambda d: sorted(d.items(), key=lambda kv: -kv[1])[:top]
    return {"flops": rank(flops_by), "bytes": rank(bytes_by)}


def print_report(hlo_text: str, top: int = 20):
    rep = attribute(hlo_text, top)
    print("== dot flops by source ==")
    for s, v in rep["flops"]:
        print(f"  {s:56s} {v:.3e}")
    print("== bytes by source ==")
    for s, v in rep["bytes"]:
        print(f"  {s:56s} {v:.3e}")
