"""Roofline-term extraction from compiled XLA artifacts (brief §Roofline).

    compute    = HLO_FLOPs       / (chips * 197e12 FLOP/s)   (bf16 v5e)
    memory     = HLO_bytes       / (chips * 819e9  B/s)      (HBM)
    collective = collective_bytes/ (chips * 50e9   B/s)      (ICI per link)

cost_analysis() reports per-DEVICE flops/bytes for SPMD-partitioned
executables; collective bytes are NOT in cost_analysis, so we parse the
post-partitioning HLO and sum result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute op (weighted
by how many times its enclosing while-loop body runs, inferred from scan
trip counts).
"""
from __future__ import annotations

import json
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind.  Ops inside while-loop
    bodies (lax.scan over layers) are multiplied by the loop trip count when
    it is statically known from the ``trip_count=N`` backend annotation or
    the standard counter pattern."""
    out = {k: 0 for k in _COLL}
    # split into computations; track which are while bodies with trip counts
    trip = _trip_counts(hlo_text)
    cur_comp, cur_mult = None, 1
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$", line)
        if line.startswith(("ENTRY", "%")) and "{" in line and "=" not in line.split("{")[0]:
            name = line.split()[0].lstrip("%").split("(")[0].rstrip()
            cur_comp = name
            cur_mult = trip.get(name, 1)
        for kind in _COLL:
            if re.search(rf"=\s*[^=]*\b{kind}(?:-start|-done)?\(", line) or \
               re.search(rf"\b{kind}(?:-start)?\(", line) and "=" in line:
                lhs = line.split("=")[0] + "=" + line.split("=")[1].split("(")[0]
                out[kind] += _shape_bytes(lhs) * cur_mult
                break
    return out


def _trip_counts(hlo_text: str) -> dict:
    """Map computation name -> trip count for counted while loops.
    XLA annotates known trip counts in backend_config or we infer from the
    constant compare in the condition; fall back to 1."""
    trips = {}
    # pattern: while(...), condition=%cond_N, body=%body_N ... trip_count
    for m in re.finditer(r'body=%?([\w.\-]+)[^\n]*?'
                         r'backend_config=.*?"known_trip_count":\{"n":"(\d+)"\}',
                         hlo_text):
        trips[m.group(1)] = int(m.group(2))
    return trips


def analyze(compiled, *, chips: int, model_flops: float | None = None) -> dict:
    from repro.launch.hlo_cost import analyze_hlo
    hlo = compiled.as_text()
    tot = analyze_hlo(hlo)          # trip-count-weighted, per device
    flops = float(tot["flops"])
    bytes_acc = float(tot["bytes"])
    coll = tot["collectives"]
    coll_total = float(tot["collective_bytes"])
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    # NOTE: cost_analysis on a partitioned executable is already per-device.
    dominant = max(terms, key=terms.get)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception as e:                               # pragma: no cover
        mem = {"error": str(e)}
    result = {
        "chips": chips,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "terms_s": terms,
        "dominant": dominant,
        "memory": mem,
    }
    if model_flops is not None:
        result["model_flops"] = model_flops
        dev_total = flops * chips
        result["useful_ratio"] = model_flops / dev_total if dev_total else 0.0
    return result


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for dense, 6*N_active*D for MoE (training); forward-only /3 for
    serving steps; decode counts a single new token per sequence."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence (attention over the cache adds the
    # S-dependent term: 2 * layers * cache_dim work — folded into n_active
    # approximation; see EXPERIMENTS.md notes)
    return 2.0 * n_active * shape.global_batch


def active_params(cfg) -> float:
    """Parameter count active per token (MoE counts top_k+shared experts)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_padded
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        sc = cfg.ssm
        di = sc.expand * d
        H = di // sc.head_dim
        per = d * (2 * di + 2 * sc.d_state + H) + di * d
        return emb + L * per
    # attention per layer
    if cfg.mla is not None:
        m = cfg.mla
        attn = (d * m.q_lora + m.q_lora * cfg.n_heads * (m.nope_dim + m.rope_dim)
                + d * (m.kv_lora + m.rope_dim)
                + m.kv_lora * cfg.n_heads * (m.nope_dim + m.v_dim)
                + cfg.n_heads * m.v_dim * d)
    elif cfg.n_heads:
        attn = d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd \
            + cfg.n_heads * cfg.hd * d
    else:
        attn = 0
    glu = 3 if cfg.act in ("swiglu", "geglu") else 2
    dense_ffn = glu * d * cfg.d_ff
    if cfg.family == "moe":
        mc = cfg.moe
        moe_ffn = glu * d * mc.d_expert * (mc.top_k + mc.n_shared) + d * mc.n_experts
        total = emb + mc.first_dense * (attn + dense_ffn) \
            + (L - mc.first_dense) * (attn + moe_ffn)
        return total
    if cfg.family == "hybrid":
        sc = cfg.ssm
        di = sc.expand * d
        H = di // sc.head_dim
        mamba = d * (2 * di + 2 * sc.d_state + H) + di * d
        n_attn = L // cfg.attn_every
        n_mamba = L - n_attn
        mc = cfg.moe
        n_moe = L // 2 if mc.every_other else L
        n_mlp = L - n_moe
        moe_ffn = glu * d * mc.d_expert * mc.top_k + d * mc.n_experts
        return emb + n_attn * attn + n_mamba * mamba \
            + n_moe * moe_ffn + n_mlp * dense_ffn
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (attn + dense_ffn)
        dec = L * (2 * attn + dense_ffn)
        return emb + enc + dec
    return emb + L * (attn + dense_ffn)
