"""Device (TPU) DCO engine: batched two-stage pruned top-k in pure JAX.

NOTE: since PR 2 the default device path is the streaming block-fused scan
in ``core.stream_engine`` (running tau, O(chunk·row_block) estimate memory);
this module keeps the engine config, the device-state builders, the
distributed wrapper, and the legacy one-shot engine
(``SchedulePolicy(engine="two_stage")``), which materializes a full
(query_chunk, N) estimate matrix per chunk.

This is the hardware adaptation of the paper's per-vector early-exit loop
(DESIGN.md §3).  Per query block:

  stage 0  rotate queries (the paper's O(D^2) online pre-processing, batched
           into one (Q,D)@(D,D) matmul);
  stage 1  partial squared distances over the leading ``d1`` rotated dims —
           one MXU matmul over a contiguous HBM stream;
  anchor   exact distances for the k best rows BY ESTIMATE (a k-row tail
           completion).  max of those k exact distances is a CERTIFIED upper
           bound tau on the true k-th distance, so for lower-bound methods
           (PDScanning/PDScanning+) the batch pipeline stays EXACT;
  stage 2  tail completion (trailing D-d1 rotated dims) only for a
           capacity-bounded set of survivors, then final top-k.

The rotated dataset is stored once, dimension-blocked, so "scan fewer
dimensions" literally becomes "stream fewer HBM bytes".

Decision rules supported (same estimators as core.methods):
  fdscan | lb (PDScanning/+) | adsampling | dade | ddcres | ratio (DDCpca)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DcoEngineConfig:
    kind: str = "lb"           # fdscan|lb|adsampling|dade|ddcres|ratio
    d1: int = 128              # stage-1 dims
    k: int = 20
    capacity: int = 2048       # stage-2 survivor capacity per query per shard
    eps0: float = 2.1          # adsampling
    z_alpha: float = 2.0       # dade
    m: float = 3.0             # ddcres
    theta: float = 1.0         # ratio (DDCpca learned threshold)
    tau_slack: float = 1.0     # extra slack on the certified tau
    query_chunk: int = 16      # queries processed per lax.map step
    # --- streaming engine (core.stream_engine) knobs; ignored by two_stage ---
    row_block: int = 4096      # candidate rows streamed per lax.scan step
    block_capacity: int = 128  # survivors tail-completed per block per query
    use_kernel: bool | None = None  # Pallas dco_scan/pq_lookup for stage 1
                                    # (None -> only on TPU; CPU uses the
                                    # numerically identical jnp block path)
    policy: object | None = None    # core.policy.PolicyConfig for the
                                    # adaptive fdscan fallback (DESIGN.md §5);
                                    # None = fixed rule (frozen dataclass so
                                    # the config stays jit-static/hashable)
    dim_groups: int = 1        # PDX vertical layout: contiguous dim groups
                               # per row block with per-group early exit
                               # (DESIGN.md §8); 1 = flat row-major layout
    group_capacity: int = 0    # jnp PDX path: candidates kept per query
                               # after the group-0 R-cut (0 = auto:
                               # max(4*block_capacity, 512), clamped to the
                               # row block)


def build_device_state(method_or_arrays, d1: int) -> dict:
    """Build the dimension-blocked device arrays from a fitted host method's
    uniform ``device_state()`` export (or a raw dict with 'Xrot').  Requires a
    full-rank rotation so that lead+tail == exact (transforms.fit_pca
    guarantees rank==D for D<=1024; ADSampling rotations are full rank up to
    max_rank)."""
    if isinstance(method_or_arrays, dict):
        extras = method_or_arrays
    else:
        extras = method_or_arrays.device_state()
    xr = np.asarray(extras["Xrot"], np.float32)
    n, D = xr.shape
    d1 = min(d1, D)
    state = {
        "x_lead": jnp.asarray(xr[:, :d1]),
        "x_tail": jnp.asarray(xr[:, d1:]),
        "lead_sq": jnp.asarray((xr[:, :d1] ** 2).sum(1)),
        "tail_sq": jnp.asarray((xr[:, d1:] ** 2).sum(1)),
    }
    state.update(rule_scalars(extras, d1))
    return state


def rule_scalars(extras: dict, d1: int) -> dict:
    """Per-rule replicated scalars the engine's _estimate needs beyond the
    dimension-blocked arrays (DADE eigen-mass/slack at d1).  Shared by
    build_device_state and the mesh path, where the sharded per-device state
    is assembled inside shard_map and these ride along as constants."""
    out = {}
    if "mass" in extras:        # dade eigen-mass at d1
        out["mass_d1"] = jnp.float32(max(float(extras["mass"][d1 - 1]), 1e-9))
        out["eps_d1"] = jnp.float32(float(extras["eps_d"][d1 - 1]))
    return out


def rotate_queries(W: jax.Array, Q: jax.Array) -> jax.Array:
    """Batched online pre-processing: one matmul amortizes the O(D^2) cost
    the paper identifies as the ultra-high-D bottleneck."""
    return Q @ W


def _estimate(cfg: DcoEngineConfig, partial, D, state, q_extra):
    d1 = cfg.d1
    if cfg.kind in ("lb", "fdscan"):
        return partial
    if cfg.kind == "adsampling":
        return partial * (D / d1) / (1.0 + cfg.eps0 / np.sqrt(d1)) ** 2
    if cfg.kind == "dade":
        return partial / state["mass_d1"] / (1.0 + state["eps_d1"]) ** 2
    if cfg.kind == "ratio":
        return partial / cfg.theta
    if cfg.kind == "ddcres":
        # full-distance estimate: lead partial + exact tail norms, minus the
        # Gaussian slack on the unscanned cross term (core.methods Eq. 7);
        # per-query scalars arrive via q_extra (see api.backends.device_prep)
        slack = 2.0 * cfg.m * jnp.sqrt(jnp.maximum(q_extra["var_d1"], 0.0))
        return (partial + state["tail_sq"][None, :]
                + q_extra["qtail_sq"][:, None] - slack[:, None])
    raise ValueError(cfg.kind)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _two_stage_topk_padded(state: dict, q_lead: jax.Array, q_tail: jax.Array,
                           q_extra: dict, cfg: DcoEngineConfig):
    """Chunked two-stage top-k; requires nq to divide into query chunks."""
    x_lead, x_tail = state["x_lead"], state["x_tail"]
    n, d1 = x_lead.shape
    D = d1 + x_tail.shape[1]
    k, C = cfg.k, min(cfg.capacity, n)

    def one_chunk(qs):
        ql, qt, qe = qs                                    # (c, d1), (c, Dt)
        # ---- stage 1: one contiguous-stream matmul --------------------
        partial = (state["lead_sq"][None, :] - 2.0 * ql @ x_lead.T
                   + (ql ** 2).sum(1)[:, None])            # (c, n)
        partial = jnp.maximum(partial, 0.0)
        est = _estimate(cfg, partial, D, state, qe)
        if cfg.kind == "fdscan":
            exact = partial + (state["tail_sq"][None, :] - 2.0 * qt @ x_tail.T
                               + (qt ** 2).sum(1)[:, None])
            dists, ids = jax.lax.top_k(-exact, k)
            return -dists, ids, jnp.full((ql.shape[0],), n, jnp.int32)
        # ---- anchor: certified tau from k exact completions -----------
        _, anchor = jax.lax.top_k(-est, k)                 # (c, k) best by estimate
        a_tail = x_tail[anchor]                            # (c, k, Dt)
        a_exact = (partial[jnp.arange(ql.shape[0])[:, None], anchor]
                   + jnp.maximum(((a_tail - qt[:, None, :]) ** 2).sum(-1), 0.0))
        tau = a_exact.max(-1) * cfg.tau_slack              # (c,) upper bound on true kth
        # ---- screening + capacity selection ---------------------------
        keep = est <= tau[:, None]
        score = jnp.where(keep, est, jnp.inf)
        neg_s, cand = jax.lax.top_k(-score, C)             # (c, C) survivors
        alive = jnp.isfinite(-neg_s)
        n_alive = alive.sum(-1).astype(jnp.int32)
        # ---- stage 2: tail completion only for survivors --------------
        c_tail = x_tail[cand]                              # (c, C, Dt)
        c_part = partial[jnp.arange(ql.shape[0])[:, None], cand]
        exact = c_part + jnp.maximum(((c_tail - qt[:, None, :]) ** 2).sum(-1), 0.0)
        exact = jnp.where(alive, exact, jnp.inf)
        dists, pos = jax.lax.top_k(-exact, k)
        ids = cand[jnp.arange(ql.shape[0])[:, None], pos]
        return -dists, ids, n_alive

    nq = q_lead.shape[0]
    c = min(cfg.query_chunk, nq)
    ql = q_lead.reshape(nq // c, c, -1)
    qt = q_tail.reshape(nq // c, c, -1)
    qe = {key: v.reshape(nq // c, c) for key, v in q_extra.items()}
    d, i, s = jax.lax.map(one_chunk, (ql, qt, qe))
    return (d.reshape(nq, k), i.reshape(nq, k), s.reshape(nq))


def two_stage_topk(state: dict, q_lead: jax.Array, q_tail: jax.Array,
                   cfg: DcoEngineConfig, q_extra: dict | None = None):
    """Top-k over the local shard for a batch of (already rotated) queries.

    q_lead (Q, d1), q_tail (Q, D - d1).  Ragged batches (``nq`` not a
    multiple of ``cfg.query_chunk``) are zero-padded to a whole number of
    chunks and the padding rows sliced off the results.  ``q_extra`` carries
    optional per-query scalars (DDCres tail norms / variance suffix).
    Returns (dists_sq (Q,k), ids (Q,k), survivors (Q,) number of stage-2
    rows actually alive).
    """
    q_extra = dict(q_extra or {})
    nq = q_lead.shape[0]
    if nq == 0:
        raise ValueError("two_stage_topk needs at least one query")
    c = min(cfg.query_chunk, nq)
    pad = (-nq) % c
    if pad:
        q_lead = jnp.pad(q_lead, ((0, pad), (0, 0)))
        q_tail = jnp.pad(q_tail, ((0, pad), (0, 0)))
        q_extra = {key: jnp.pad(v, (0, pad)) for key, v in q_extra.items()}
    d, i, s = _two_stage_topk_padded(state, q_lead, q_tail, q_extra, cfg)
    return d[:nq], i[:nq], s[:nq]


def _aligned_row_block(per_shard: int, row_block: int) -> int:
    """The largest divisor of ``per_shard`` that is <= ``row_block`` — the
    biggest certificate-safe streaming block for a mesh shard of that size
    (worst case 1, which is always safe)."""
    rb = max(1, min(int(row_block), int(per_shard)))
    while per_shard % rb:
        rb -= 1
    return rb


def make_distributed_topk(mesh, cfg: DcoEngineConfig, shard_axes=("data", "model"),
                          extra_state: dict | None = None, engine: str = "stream",
                          n_rows: int | None = None):
    """shard_map engine: dataset rows sharded over ``shard_axes``; queries
    (and per-query ``q_extra`` scalars) replicated; local top-k per shard
    then all-gather + global merge.  The local engine is the streaming
    block-fused scan (core.stream_engine, the default) or the legacy
    ``two_stage`` materializing engine.  ``extra_state`` carries the
    replicated rule scalars from :func:`rule_scalars` (e.g. DADE
    mass_d1/eps_d1).  Returns (dists (Q, k), ids (Q, k), survivors (Q,),
    dropped_min_est (Q,)) — survivors is the REAL number of stage-2
    completions summed over all shards (psum), not a capacity bound;
    dropped_min_est is the global (pmin) exactness certificate of the
    streaming engine, +inf for the two-stage engine.

    ``n_rows`` (the total sharded row count) arms build-time validation of
    the certificate sharp edge: when a shard's row count is not a
    ``row_block`` multiple, the per-shard streaming layout pads the last
    block with zero rows *inside* the compiled call, and those phantom
    rows' estimates can sit under the running tau — weakening each shard's
    dropped-estimate certificate (and, through the pmin merge, the global
    one).  Passing ``n_rows`` makes that misalignment a clear build-time
    error instead of a silently weaker certificate; the jax backend's mesh
    path auto-aligns ``row_block`` to the shard size before calling, so
    facade sessions never hit it.  ``None`` preserves the old
    caller-beware behavior."""
    from jax.sharding import PartitionSpec as P
    import jax.experimental.shard_map as shard_map

    if engine not in ("stream", "two_stage"):
        raise ValueError(f"engine must be 'stream' or 'two_stage', got {engine!r}")
    if cfg.policy is not None and getattr(cfg.policy, "adaptive", False):
        raise ValueError(
            "the adaptive DCO policy is single-device for now — drop "
            "SchedulePolicy(adaptive=True) on the mesh path (DESIGN.md §5)")
    if n_rows is not None:
        n_shards = 1
        for a in shard_axes:
            n_shards *= mesh.shape[a]
        per_shard, rem = divmod(int(n_rows), n_shards)
        if rem:
            raise ValueError(
                f"make_distributed_topk: {n_rows} rows do not shard evenly "
                f"over {n_shards} devices ({shard_axes}); pad the corpus to "
                f"a multiple of {n_shards} rows before sharding")
        if engine == "stream" and per_shard % cfg.row_block:
            raise ValueError(
                f"make_distributed_topk: shard size {per_shard} is not a "
                f"multiple of row_block={cfg.row_block} — the per-shard "
                "streaming layout would pad the last block with phantom "
                "zero rows, weakening every shard's exactness certificate "
                "(DESIGN.md §4/§10).  Use a row_block that divides the "
                f"shard size (e.g. {_aligned_row_block(per_shard, cfg.row_block)}) "
                "or pad the corpus; the facade's mesh path auto-aligns")
    extra_state = dict(extra_state or {})

    def local_fn(x_lead, x_tail, lead_sq, tail_sq, q_lead, q_tail, q_extra):
        state = {"x_lead": x_lead, "x_tail": x_tail,
                 "lead_sq": lead_sq, "tail_sq": tail_sq, **extra_state}
        if engine == "stream":
            from repro.core.stream_engine import stream_topk
            d, i, surv, _, dmin, _ = stream_topk(state, q_lead, q_tail, cfg,
                                                 q_extra)
        else:
            d, i, surv = two_stage_topk(state, q_lead, q_tail, cfg, q_extra)
            dmin = jnp.full(d.shape[0], jnp.inf)
        # globalize ids with the shard's row offset
        idx = jax.lax.axis_index(shard_axes[0])
        if len(shard_axes) > 1:
            for a in shard_axes[1:]:
                idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        i = i + idx * x_lead.shape[0]
        # all-gather per-shard top-k and merge
        dg = jax.lax.all_gather(d, shard_axes, tiled=False)   # (S, Q, k)
        ig = jax.lax.all_gather(i, shard_axes, tiled=False)
        dg = jnp.moveaxis(dg, 0, 1).reshape(d.shape[0], -1)   # (Q, S*k)
        ig = jnp.moveaxis(ig, 0, 1).reshape(d.shape[0], -1)
        best, pos = jax.lax.top_k(-dg, cfg.k)
        surv = jax.lax.psum(surv, shard_axes)   # real completions, all shards
        dmin = jax.lax.pmin(dmin, shard_axes)   # weakest shard certificate
        return -best, jnp.take_along_axis(ig, pos, axis=1), surv, dmin

    spec_x = P(shard_axes)      # rows sharded over the product of axes
    return shard_map.shard_map(
        local_fn, mesh=mesh,
        in_specs=(spec_x, spec_x, spec_x, spec_x, P(), P(), P()),
        out_specs=(P(), P(), P(), P()),
        check_rep=False,
    )
