"""Adaptive DCO policy engine: notice when screening stops paying, fall back.

The paper's central negative result is that DCO screening is *not* a silver
bullet: pruning power collapses under out-of-distribution queries and shifts
with dimensionality and hardware, sometimes landing slower than a plain
full-dimensional scan.  A production session therefore cannot hard-code one
rule: this module turns the engines' per-block telemetry (survivor counts —
already produced by the streaming engine of DESIGN.md §4) into a running
cost model and a jit-compatible decision that degrades the active screening
rule to ``fdscan`` — the thing that is never wrong — while it is losing, and
returns to screening on recovery.  DESIGN.md §5 is the narrative reference.

Cost model (all quantities per candidate row, in scanned dims):

    screened cost  ~  d_screen + pass_fraction * d_complete + overhead_dims
    fdscan cost    ~  D

``pass_fraction`` is the fraction of a block's rows that survive the screen
(the engines measure it per block; an EWMA smooths it).  Screening is
predicted net-positive while

    fallback_margin * screened_cost  <=  fdscan_cost

which solves to the survivor-fraction threshold of :func:`pass_threshold`.
``fallback_margin > 1`` demands screening beat the full scan by that factor
before it is trusted (headroom for the compaction / merge work the dim
count does not see); ``overhead_dims`` charges the fixed per-row cost of
screening bookkeeping in dim units.

Certified-fallback invariant (DESIGN.md §5): a fallback decision only ever
*adds* scanned dims — fallback blocks complete every candidate row exactly,
so the exactness certificate of the streaming engine (``dropped_min_est``)
and the host scan's exhaustive completion are unaffected.  Adaptive mode can
restore certification that a fixed rule loses (a fallback block drops
nothing), never the reverse.
"""
from __future__ import annotations

import dataclasses

from repro.core.engine import (EXTRA_EST_SAVED_FLOPS, EXTRA_FALLBACK_BLOCKS,
                               EXTRA_RULE_TIMELINE)

#: private ScanStats.extra accumulator used by the host scan between
#: ``scan_topk`` calls; :func:`finalize_adaptive_extra` folds it into the
#: public keys and removes it.
_ACC_KEY = "_adaptive_acc"


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Static knobs of the adaptive policy (hashable: rides inside the
    jit-static ``DcoEngineConfig``).

    ``fallback_margin`` — how much cheaper than fdscan the cost model must
    predict screening to be before it stays active (DESIGN.md §5 tuning
    guidance).  ``ewma_alpha`` — weight of the newest block's survivor
    fraction in the running estimate.  ``overhead_dims`` — fixed per-row
    screening overhead in dim units (compaction, merges).  ``hysteresis`` —
    fraction of the entry threshold the EWMA must drop below before the
    policy flips back to screening (avoids mode thrash at the boundary).
    ``force_fallback`` — pin the policy in fallback: every block/chunk runs
    the dedicated certified full-scan body and never returns to screening.
    This is the guardrail breaker's demotion lever (DESIGN.md §9): the OPEN
    state serves batches through a config with ``force_fallback=True``,
    reusing the same jitted ``step_full`` graph the adaptive escape uses.
    """

    adaptive: bool = True
    fallback_margin: float = 1.5
    ewma_alpha: float = 0.5
    overhead_dims: float = 8.0
    hysteresis: float = 0.9
    force_fallback: bool = False

    @classmethod
    def from_schedule(cls, schedule) -> "PolicyConfig | None":
        """Build from a facade ``SchedulePolicy``; None when not adaptive."""
        if not getattr(schedule, "adaptive", False):
            return None
        return cls(adaptive=True, fallback_margin=schedule.fallback_margin)


def pass_threshold(D: int, d_screen: float, d_complete: float,
                   margin: float, overhead_dims: float) -> float:
    """Survivor-fraction threshold above which screening is predicted
    net-negative.

    Solves ``margin * (d_screen + f * d_complete + overhead_dims) == D`` for
    ``f``.  A result <= 0 means screening can never pay at this geometry
    (e.g. ``d_screen`` ~ D): the policy then serves every block by fdscan.
    A result >= 1 means screening always pays in this model and the policy
    never falls back.
    """
    return (D / max(margin, 1e-9) - d_screen - overhead_dims) / max(d_complete, 1.0)


class HostPolicy:
    """Mutable per-query mirror of the scan policy for the host engine.

    The host staged scan (``core.engine.scan_topk``) completes every screen
    survivor exhaustively, so host adaptivity is purely a performance
    feature — results are unchanged by construction (the fallback invariant
    is trivial).  The decision is history-based: block ``t`` is served by
    the mode implied by blocks ``< t``.  In fallback mode a first-stage
    *shadow* screen (cheap: ``stages[0]`` dims per row) keeps the survivor
    signal alive so the policy can flip back on recovery; its cost is
    charged to ``dims_scanned`` like any real screening work.
    """

    def __init__(self, cfg: PolicyConfig, D: int):
        self.cfg = cfg
        self.D = float(D)
        # force_fallback (the guardrail demotion) starts AND stays in
        # fallback: every candidate block completes exactly
        self.mode = bool(cfg.force_fallback)
        self.ewma = 0.0
        self._n_obs = 0
        self.fallback_blocks = 0
        self.saved_flops = 0.0
        self.timeline: list[bool] = []

    def block_served(self, fallback: bool, n: int, completed: int,
                     charged_dims: float) -> None:
        """Record how a candidate block was actually served.

        ``n`` candidate rows, ``completed`` rows exactly completed,
        ``charged_dims`` total screening dims charged for the block.
        ``est_saved_flops`` accumulates the measured saving vs an
        always-fdscan baseline (2 FLOPs per row-dim, fused multiply-add).
        """
        self.timeline.append(bool(fallback))
        if fallback:
            self.fallback_blocks += 1
            # fallback pays the shadow screen on top of the full scan
            self.saved_flops -= 2.0 * charged_dims
        else:
            self.saved_flops += 2.0 * ((n - completed) * self.D - charged_dims)

    def observe(self, n: int, n_pass: int, d_screen: float) -> None:
        """Fold one block's survivor fraction into the EWMA and re-decide.

        ``d_screen`` is the measured per-row screening dims of this block
        (the shadow stage's dims while in fallback), so the threshold tracks
        what screening actually costs on this scan.
        """
        if n <= 0 or self.cfg.force_fallback:
            return                  # demoted: the mode never flips back
        frac = n_pass / n
        a = self.cfg.ewma_alpha
        self.ewma = frac if self._n_obs == 0 else a * frac + (1 - a) * self.ewma
        self._n_obs += 1
        thr = pass_threshold(self.D, d_screen, self.D,
                             self.cfg.fallback_margin, self.cfg.overhead_dims)
        if self.mode:
            self.mode = self.ewma > thr * self.cfg.hysteresis
        else:
            self.mode = self.ewma > thr

    def flush(self, stats) -> None:
        """Accumulate this query's telemetry into ``stats.extra`` (private
        accumulator; the backend calls :func:`finalize_adaptive_extra` once
        per batch to produce the public keys)."""
        if stats is None:
            return
        acc = stats.extra.setdefault(
            _ACC_KEY, {"fb": 0, "saved": 0.0, "nq": 0, "tl_fb": [], "tl_n": []})
        acc["fb"] += self.fallback_blocks
        acc["saved"] += self.saved_flops
        acc["nq"] += 1
        for b, fb in enumerate(self.timeline):
            while len(acc["tl_fb"]) <= b:
                acc["tl_fb"].append(0)
                acc["tl_n"].append(0)
            acc["tl_fb"][b] += int(fb)
            acc["tl_n"][b] += 1


def finalize_adaptive_extra(stats) -> None:
    """Convert the host accumulator into the public ``ScanStats.extra``
    telemetry keys (``fallback_blocks`` mean per query, ``est_saved_flops``
    batch total, ``rule_timeline`` per-block fallback fraction) — the same
    keys the jax backend reports, so host and device runs are comparable."""
    acc = stats.extra.pop(_ACC_KEY, None)
    if acc is None or acc["nq"] == 0:
        return
    stats.extra[EXTRA_FALLBACK_BLOCKS] = acc["fb"] / acc["nq"]
    stats.extra[EXTRA_EST_SAVED_FLOPS] = acc["saved"]
    stats.extra[EXTRA_RULE_TIMELINE] = [
        f / max(n, 1) for f, n in zip(acc["tl_fb"], acc["tl_n"])]
