"""The 8 DCO methods of the paper, in a unified batched form.

Taxonomy (paper §III):
  simple scanning      : FDScanning, PDScanning, PDScanning+
  hypothesis testing   : ADSampling, DADE, DDCres
  classification based : DDCpca, DDCopq

TPU adaptation (DESIGN.md §3): the per-vector `while d < D: if dis' > τ`
loop becomes *staged screening over candidate blocks*.  A method exposes a
``screen(ids, ctx, qi, d, tau_sq) -> keep_mask`` operation per stage plus an
``exact_sq`` completion in ORIGINAL coordinates, so every method is exact for
the survivors and differs only in what it prunes.  The numpy backend below is
the host reference (used by the HNSW index and the CPU benchmarks); the JAX /
Pallas engines consume the same fitted state.

All arithmetic is in SQUARED Euclidean distance (monotone equivalent).
"""
from __future__ import annotations

import numpy as np

from repro.core import transforms as T

# ---------------------------------------------------------------------------


class DCOMethod:
    """Base class.  Subclasses set ``name`` / ``exact`` and implement hooks.

    docs/methods.md is the operator's guide to all 8 methods (math sketch,
    exactness, training, device support, when-to-use matrix).
    """

    name: str = "base"
    exact: bool = True          # never prunes a true positive
    needs_training: bool = False

    def __init__(self, **params):
        self.params = params
        self.state: dict = {}

    # -- offline ------------------------------------------------------------
    def fit(self, X: np.ndarray):
        """Fit on the base vectors: store X/norms, then the method hook."""
        X = np.asarray(X, np.float32)
        self.state["X"] = X
        self.state["N"], self.state["D"] = X.shape
        self.state["norms"] = (X ** 2).sum(1)
        self._fit(X)
        return self

    def _fit(self, X):  # override
        pass

    def append(self, Xnew: np.ndarray):
        """Incremental insert support (paper §V-E): extend stored arrays
        WITHOUT refitting the transforms — the dynamic-data scenario."""
        Xnew = np.asarray(Xnew, np.float32)
        self.state["X"] = np.concatenate([self.state["X"], Xnew])
        self.state["norms"] = np.concatenate([self.state["norms"], (Xnew ** 2).sum(1)])
        self._append(Xnew)
        self.state["N"] = self.state["X"].shape[0]

    def _append(self, Xnew):  # override if method keeps derived arrays
        pass

    # -- online -------------------------------------------------------------
    def prep_queries(self, Q: np.ndarray) -> dict:
        """Per-query online pre-processing (the O(D^2) cost the paper flags).
        Batched: rotations become a single (Q,D)@(D,r) matmul."""
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        return self._prep(Q) | {"Q": Q, "qnorms": (Q ** 2).sum(1)}

    def _prep(self, Q) -> dict:
        return {}

    def stage_dims(self, schedule) -> list:
        """Screening stages actually used (methods may cap at their rank)."""
        return [d for d in schedule if d < self.state["D"]]

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Return (keep_mask, dims_charged). keep=True means 'cannot prune yet'."""
        raise NotImplementedError

    def exact_sq(self, ids, ctx, qi):
        """Exact squared distances in ORIGINAL coordinates for ``ids``."""
        X, q = self.state["X"], ctx["Q"][qi]
        diff = X[ids] - q
        return np.einsum("nd,nd->n", diff, diff)

    # -- device export --------------------------------------------------------
    def device_state(self) -> dict:
        """Uniform export consumed by the JAX engine (jax_engine).

        Every method returns a dict with at least:
          kind -- the engine decision rule (fdscan|lb|adsampling|dade|ddcres|ratio)
          Xrot -- (N, r) row matrix the device streams (raw X when identity)
          W    -- (D, r) query rotation, or None for identity
          mean -- (D,) query centering, or None
        plus rule-specific arrays (e.g. ``mass``/``eps_d`` for DADE).  The
        default is exact lower-bound screening over the raw coordinates —
        valid for any method, since partial ssd on original dims never prunes
        a true neighbor.
        """
        return {"kind": "lb", "Xrot": self.state["X"], "W": None, "mean": None}


# ---------------------------------------------------------------------------
# Simple scanning
# ---------------------------------------------------------------------------


class FDScanning(DCOMethod):
    """Full-dimension scan: no screening stages at all (docs/methods.md)."""

    name = "FDScanning"
    exact = True

    def stage_dims(self, schedule):
        """No screening stages: every candidate completes exactly."""
        return []

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Keep everything; charge no dims (there is no screen)."""
        return np.ones(len(ids), bool), 0

    def device_state(self):
        """Engine rule ``fdscan`` over the raw coordinates."""
        return {"kind": "fdscan", "Xrot": self.state["X"], "W": None, "mean": None}


class PDScanning(DCOMethod):
    """Partial-dimension scan on ORIGINAL dims: partial ssd is an exact lower
    bound, so pruning at ``partial > tau`` is exact (docs/methods.md)."""

    name = "PDScanning"
    exact = True

    def _partial(self, ids, ctx, qi, d):
        X, q = self.state["X"], ctx["Q"][qi]
        diff = X[ids, :d] - q[:d]
        return np.einsum("nd,nd->n", diff, diff)

    def partial_range(self, ids, ctx, qi, lo, hi):
        """Partial ssd over the dim slice [lo, hi) only — the strided group
        read scan_topk accumulates across stages instead of recomputing the
        whole prefix per stage (host PDX mirror, DESIGN.md §8)."""
        X, q = self.state["X"], ctx["Q"][qi]
        diff = X[ids, lo:hi] - q[lo:hi]
        return np.einsum("nd,nd->n", diff, diff)

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Exact lower-bound test: partial ssd over the leading ``d`` dims."""
        return self._partial(ids, ctx, qi, d) <= tau_sq, d


class PDScanningPlus(PDScanning):
    """PDScanning on PCA-rotated dims (variance-ordered -> earlier exits).
    Still exact: partial sums over orthonormal directions lower-bound dis^2
    (docs/methods.md)."""

    name = "PDScanning+"
    exact = True

    def _fit(self, X):
        self.state["pca"] = self.params.get("pca") or T.fit_pca(X, seed=self.params.get("seed", 0))
        self.state["Xrot"] = T.pca_rotate(self.state["pca"], X)

    def _append(self, Xnew):
        self.state["Xrot"] = np.concatenate(
            [self.state["Xrot"], T.pca_rotate(self.state["pca"], Xnew)])

    def _prep(self, Q):
        return {"Qrot": T.pca_rotate(self.state["pca"], Q)}

    def stage_dims(self, schedule):
        """Stages capped at the PCA rank (rotated dims beyond it are 0)."""
        r = self.state["pca"]["rank"]
        return [d for d in schedule if d < min(r, self.state["D"])]

    def _partial(self, ids, ctx, qi, d):
        diff = self.state["Xrot"][ids, :d] - ctx["Qrot"][qi, :d]
        return np.einsum("nd,nd->n", diff, diff)

    def partial_range(self, ids, ctx, qi, lo, hi):
        """Partial ssd over the rotated dim slice [lo, hi) — the incremental
        group read of the host PDX scan (see PDScanning.partial_range)."""
        diff = self.state["Xrot"][ids, lo:hi] - ctx["Qrot"][qi, lo:hi]
        return np.einsum("nd,nd->n", diff, diff)

    def device_state(self):
        """Engine rule ``lb`` over the PCA-rotated corpus."""
        return {"kind": "lb", "Xrot": self.state["Xrot"],
                "W": self.state["pca"]["W"], "mean": None}


# ---------------------------------------------------------------------------
# Hypothesis testing
# ---------------------------------------------------------------------------


class ADSampling(DCOMethod):
    """Gao & Long [1]: JL rotation; est = sqrt(D/d) * partial; reject H0 when
    est > (1 + eps0/sqrt(d)) * tau (docs/methods.md)."""

    name = "ADSampling"
    exact = False

    def _fit(self, X):
        rot = T.fit_random_rotation(self.state["D"], seed=self.params.get("seed", 0))
        self.state["rot"] = rot
        self.state["Xrot"] = X @ rot["P"]

    def _append(self, Xnew):
        self.state["Xrot"] = np.concatenate([self.state["Xrot"], Xnew @ self.state["rot"]["P"]])

    def _prep(self, Q):
        return {"Qrot": Q @ self.state["rot"]["P"]}

    def stage_dims(self, schedule):
        """Stages capped at the random-rotation rank."""
        r = self.state["rot"]["rank"]
        return [d for d in schedule if d < min(r, self.state["D"])]

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Scaled-partial hypothesis test at significance ``eps0``."""
        diff = self.state["Xrot"][ids, :d] - ctx["Qrot"][qi, :d]
        partial = np.einsum("nd,nd->n", diff, diff)
        eps0 = self.params.get("eps0", 2.1)
        D = self.state["D"]
        bound = tau_sq * (1.0 + eps0 / np.sqrt(d)) ** 2
        return partial * (D / d) <= bound, d

    def device_state(self):
        """Engine rule ``adsampling`` (JL-rotated corpus + eps0)."""
        return {"kind": "adsampling", "Xrot": self.state["Xrot"],
                "W": self.state["rot"]["P"], "mean": None,
                "eps0": self.params.get("eps0", 2.1)}


class DADE(DCOMethod):
    """Deng et al. [2]: PCA rotation; eigen-mass-scaled unbiased estimator with
    a significance-level bound (Eq. 2) (docs/methods.md)."""

    name = "DADE"
    exact = False

    def _fit(self, X):
        pca = self.params.get("pca") or T.fit_pca(X, seed=self.params.get("seed", 0))
        self.state["pca"] = pca
        self.state["Xrot"] = T.pca_rotate(pca, X)
        lam = pca["eigvals"].astype(np.float64)
        total = max(float(pca["total_var"]), float(lam.sum()))
        cum = np.cumsum(lam)
        self.state["mass"] = (cum / total).astype(np.float32)       # sum_{<=d} / sum_all
        # eps_d: relative slack from the residual eigen-mass at significance
        # alpha (z_alpha * sqrt residual fraction); alpha is empirical (paper).
        z = self.params.get("z_alpha", 2.0)
        resid = np.clip(1.0 - cum / total, 0.0, None)
        self.state["eps_d"] = (z * np.sqrt(resid / np.maximum(cum / total, 1e-9))
                               ).astype(np.float32)

    def _append(self, Xnew):
        self.state["Xrot"] = np.concatenate(
            [self.state["Xrot"], T.pca_rotate(self.state["pca"], Xnew)])

    def _prep(self, Q):
        return {"Qrot": T.pca_rotate(self.state["pca"], Q)}

    def stage_dims(self, schedule):
        """Stages capped at the PCA rank."""
        r = self.state["pca"]["rank"]
        return [d for d in schedule if d < min(r, self.state["D"])]

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Eigen-mass-scaled estimate vs the eps_d significance bound."""
        diff = self.state["Xrot"][ids, :d] - ctx["Qrot"][qi, :d]
        partial = np.einsum("nd,nd->n", diff, diff)
        mass = max(float(self.state["mass"][d - 1]), 1e-9)
        est = partial / mass                       # unbiased under eigen-mass scaling
        eps = float(self.state["eps_d"][d - 1])
        return est <= tau_sq * (1.0 + eps) ** 2, d

    def device_state(self):
        """Engine rule ``dade`` (rotated corpus + mass/eps_d arrays)."""
        return {"kind": "dade", "Xrot": self.state["Xrot"],
                "W": self.state["pca"]["W"], "mean": None,
                "mass": self.state["mass"], "eps_d": self.state["eps_d"]}


class DDCres(DCOMethod):
    """Yang et al. [3] residual cross-term estimator: norm decomposition +
    Gaussian bound on the unscanned cross term (Eqs. 4-7), tightened by PCA
    (docs/methods.md)."""

    name = "DDCres"
    exact = False

    def _fit(self, X):
        pca = self.params.get("pca") or T.fit_pca(X, seed=self.params.get("seed", 0))
        self.state["pca"] = pca
        Xc = X - pca["mean"]
        self.state["Xrot"] = Xc @ pca["W"]                  # centered + rotated
        self.state["cnorms"] = (Xc ** 2).sum(1)             # ||o||^2 centered
        lam = pca["eigvals"].astype(np.float64)
        total = max(float(pca["total_var"]), float(lam.sum()))
        self.state["sigma_sq"] = lam.astype(np.float32)     # per-dim variance
        # average variance assigned to the un-materialized tail (rank < D)
        r, D = pca["rank"], self.state["D"]
        tail = max(total - float(lam.sum()), 0.0)
        self.state["tail_var"] = np.float32(tail / max(D - r, 1))

    def _append(self, Xnew):
        pca = self.state["pca"]
        Xc = Xnew - pca["mean"]
        self.state["Xrot"] = np.concatenate([self.state["Xrot"], Xc @ pca["W"]])
        self.state["cnorms"] = np.concatenate([self.state["cnorms"], (Xc ** 2).sum(1)])

    def _prep(self, Q):
        pca = self.state["pca"]
        Qc = Q - pca["mean"]
        Qrot = Qc @ pca["W"]
        # suffix sums of q_i^2 * sigma_i^2 over rotated dims (Eq. 6)
        qs = (Qrot ** 2) * self.state["sigma_sq"][None, :]
        suffix = np.concatenate(
            [np.cumsum(qs[:, ::-1], axis=1)[:, ::-1], np.zeros((Q.shape[0], 1), np.float32)],
            axis=1)
        # tail beyond materialized rank: residual query energy * avg tail var
        qres = np.clip((Qc ** 2).sum(1) - (Qrot ** 2).sum(1), 0.0, None)
        tail = qres * self.state["tail_var"]
        return {"Qrot": Qrot, "qcnorms": (Qc ** 2).sum(1),
                "var_suffix": suffix + tail[:, None]}

    def stage_dims(self, schedule):
        """Stages capped at the PCA rank."""
        r = self.state["pca"]["rank"]
        return [d for d in schedule if d < min(r, self.state["D"])]

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Eq. 7 lower-bound estimate with Gaussian cross-term slack."""
        cross = self.state["Xrot"][ids, :d] @ ctx["Qrot"][qi, :d]
        dis_p = self.state["cnorms"][ids] + ctx["qcnorms"][qi] - 2.0 * cross
        m = self.params.get("m", 3.0)
        var = float(ctx["var_suffix"][qi, d])
        est = dis_p - 2.0 * m * np.sqrt(max(var, 0.0))      # Eq. 7 lower bound
        return est <= tau_sq, d

    def device_state(self):
        """Engine rule ``ddcres`` (centered rotation + variance scalars)."""
        pca = self.state["pca"]
        return {"kind": "ddcres", "Xrot": self.state["Xrot"],
                "W": pca["W"], "mean": pca["mean"],
                "sigma_sq": self.state["sigma_sq"],
                "tail_var": self.state["tail_var"],
                "m": self.params.get("m", 3.0)}


# ---------------------------------------------------------------------------
# Classification based
# ---------------------------------------------------------------------------


class DDCpca(DCOMethod):
    """Yang et al. [3]: per-(k, d) linear model on (partial, tau).  We use the
    scale-free form  prune <=> partial_sq > theta_{k,d} * tau_sq, with
    theta calibrated on index-generated training samples to a target
    false-prune rate (the 'linear model M_{k,d}' of Alg. 3)
    (docs/methods.md)."""

    name = "DDCpca"
    exact = False
    needs_training = True

    def _fit(self, X):
        pca = self.params.get("pca") or T.fit_pca(X, seed=self.params.get("seed", 0))
        self.state["pca"] = pca
        self.state["Xrot"] = T.pca_rotate(pca, X)
        self.state["models"] = {}   # (k, d) -> theta

    def _append(self, Xnew):
        self.state["Xrot"] = np.concatenate(
            [self.state["Xrot"], T.pca_rotate(self.state["pca"], Xnew)])

    def _prep(self, Q):
        return {"Qrot": T.pca_rotate(self.state["pca"], Q)}

    def stage_dims(self, schedule):
        """Stages capped at the PCA rank."""
        r = self.state["pca"]["rank"]
        return [d for d in schedule if d < min(r, self.state["D"])]

    def train(self, sample_queries: np.ndarray, k: int, schedule,
              *, candidates_per_query: int = 2048, fpr: float = 0.002, seed: int = 0):
        """Offline phase of Alg. 3: sampled queries + a fixed candidate
        generator produce (partial, tau, label) samples per stage d."""
        rng = np.random.default_rng(seed)
        ctx = self.prep_queries(sample_queries)
        N = self.state["N"]
        ratios = {d: [] for d in self.stage_dims(schedule)}
        for qi in range(sample_queries.shape[0]):
            ids = rng.choice(N, size=min(candidates_per_query, N), replace=False)
            full = self.exact_sq(ids, ctx, qi)
            tau_sq = np.partition(full, k - 1)[k - 1]
            pos = full <= tau_sq                      # true "dis <= tau" rows
            if not pos.any():
                continue
            for d in ratios:
                diff = self.state["Xrot"][ids, :d] - ctx["Qrot"][qi, :d]
                partial = np.einsum("nd,nd->n", diff, diff)
                ratios[d].append(partial[pos] / max(float(tau_sq), 1e-12))
        for d, r in ratios.items():
            allr = np.concatenate(r) if r else np.array([1.0])
            # keep everything below the (1-fpr) quantile of positives' ratio
            self.state["models"][(k, d)] = float(np.quantile(allr, 1.0 - fpr))
        self.state["trained_k"] = k
        return self

    def screen(self, ids, ctx, qi, d, tau_sq):
        """Trained ratio test; untrained stages keep everything."""
        k = self.state.get("trained_k")
        theta = self.state["models"].get((k, d))
        if theta is None:                      # untrained stage: keep all
            return np.ones(len(ids), bool), 0
        diff = self.state["Xrot"][ids, :d] - ctx["Qrot"][qi, :d]
        partial = np.einsum("nd,nd->n", diff, diff)
        return partial <= theta * tau_sq, d

    def device_state(self):
        """Engine rule ``ratio`` (rotated corpus + trained thetas)."""
        return {"kind": "ratio", "Xrot": self.state["Xrot"],
                "W": self.state["pca"]["W"], "mean": None,
                "models": dict(self.state["models"]),
                "trained_k": self.state.get("trained_k")}


class DDCopq(DCOMethod):
    """Yang et al. [3]: single per-k linear model on the PQ approximate
    distance; negatives verified by a full scan (Alg. 3 variant)
    (docs/methods.md)."""

    name = "DDCopq"
    exact = False
    needs_training = True

    def _fit(self, X):
        self.state["pq"] = T.fit_pq(
            X, n_sub=self.params.get("n_sub", 16),
            n_codes=self.params.get("n_codes", 256),
            seed=self.params.get("seed", 0))
        self.state["models"] = {}

    def _append(self, Xnew):
        pq = self.state["pq"]
        pq["codes"] = np.concatenate([pq["codes"], T.pq_encode(pq, Xnew)])

    def _prep(self, Q):
        luts = np.stack([T.pq_query_lut(self.state["pq"], q) for q in Q])
        return {"luts": luts}

    def stage_dims(self, schedule):
        """A single PQ screening stage; the dim argument is unused."""
        return [0]

    def train(self, sample_queries: np.ndarray, k: int, schedule=None,
              *, candidates_per_query: int = 2048, fpr: float = 0.002, seed: int = 0):
        """Calibrate the per-k adist threshold on sampled queries (Alg. 3)."""
        rng = np.random.default_rng(seed)
        ctx = self.prep_queries(sample_queries)
        N = self.state["N"]
        ratios = []
        for qi in range(sample_queries.shape[0]):
            ids = rng.choice(N, size=min(candidates_per_query, N), replace=False)
            full = self.exact_sq(ids, ctx, qi)
            tau_sq = np.partition(full, k - 1)[k - 1]
            pos = full <= tau_sq
            if not pos.any():
                continue
            adist = T.pq_adist(self.state["pq"], ctx["luts"][qi], self.state["pq"]["codes"][ids])
            ratios.append(adist[pos] / max(float(tau_sq), 1e-12))
        allr = np.concatenate(ratios) if ratios else np.array([1.0])
        self.state["models"][k] = float(np.quantile(allr, 1.0 - fpr))
        self.state["trained_k"] = k
        return self

    def screen(self, ids, ctx, qi, d, tau_sq):
        """PQ-adist ratio test; charges n_sub 'dims' for the LUT pass."""
        k = self.state.get("trained_k")
        theta = self.state["models"].get(k)
        if theta is None:
            return np.ones(len(ids), bool), 0
        adist = T.pq_adist(self.state["pq"], ctx["luts"][qi], self.state["pq"]["codes"][ids])
        n_sub = self.state["pq"]["books"].shape[0]
        return adist <= theta * tau_sq, n_sub   # charge n_sub 'dims' for the LUT pass

    def device_state(self):
        """Engine rule ``opq`` when trained; exact-lb fallback otherwise."""
        theta = self.state["models"].get(self.state.get("trained_k"))
        if theta is None:
            # untrained: fall back to exact lower-bound screening on raw dims
            return {"kind": "lb", "Xrot": self.state["X"], "W": None,
                    "mean": None}
        # native device screening: the pq_lookup Pallas kernel turns the LUT
        # gather into a one-hot matmul per candidate block (streaming engine
        # rule "opq"); survivors complete exact distances in original coords
        pq = self.state["pq"]
        return {"kind": "opq", "Xrot": self.state["X"], "W": None, "mean": None,
                "codes": pq["codes"], "books": pq["books"],
                "splits": pq["splits"], "theta": float(theta),
                "trained_k": self.state.get("trained_k")}


# ---------------------------------------------------------------------------

ALL_METHODS = {
    "FDScanning": FDScanning,
    "PDScanning": PDScanning,
    "PDScanning+": PDScanningPlus,
    "ADSampling": ADSampling,
    "DADE": DADE,
    "DDCres": DDCres,
    "DDCpca": DDCpca,
    "DDCopq": DDCopq,
}

BASELINES = ("FDScanning", "PDScanning", "PDScanning+")
SOTA = ("ADSampling", "DADE", "DDCres", "DDCpca", "DDCopq")


def make_method(name: str, **params) -> DCOMethod:
    """Instantiate one of the paper's 8 methods by facade name."""
    return ALL_METHODS[name](**params)
