"""Offline pre-processing shared by the DCO methods.

All fitting happens on the host in numpy (mirroring the paper, which uses
Python for PCA / model training and C++ only for the online path).  The
fitted state is a plain dict of numpy arrays so the JAX engine, the numpy
engine and the Pallas kernels can all consume it.

Ultra-high-D note (DESIGN.md §3): when ``D`` is too large for a dense
eigendecomposition we fit the leading ``r = min(N, D, max_rank)`` principal
directions by economy SVD.  Stage-1 partial distances over *any* orthonormal
set of directions are valid Euclidean lower bounds, and stage-2 always
recomputes the exact distance in the ORIGINAL coordinates, so correctness is
unaffected; only the tail of the eigen-spectrum used by DADE/DDCres estimates
is then approximated through the (exactly known) total variance.
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# PCA rotation (PDScanning+, DADE, DDCres, DDCpca)
# ---------------------------------------------------------------------------


def fit_pca(X: np.ndarray, *, max_rank: int = 2048, seed: int = 0) -> dict:
    """Fit a distance-preserving PCA rotation.

    Returns dict with:
      mean (D,), W (D, r) orthonormal loading columns ordered by descending
      eigenvalue, eigvals (r,), total_var (scalar; exact trace of covariance),
      rank r.
    """
    X = np.asarray(X, np.float32)
    n, d = X.shape
    mean = X.mean(axis=0)
    Xc = X - mean
    total_var = float((Xc ** 2).sum() / max(1, n - 1))
    r = min(n, d, max_rank)
    if d <= 1024 and n >= d:  # exact eigendecomposition is cheap here
        cov = (Xc.T @ Xc) / max(1, n - 1)
        evals, evecs = np.linalg.eigh(cov)
        order = np.argsort(evals)[::-1]
        W = evecs[:, order].astype(np.float32)
        eigvals = np.clip(evals[order], 0.0, None).astype(np.float32)
        r = d
    else:  # economy SVD on (possibly subsampled) data
        m = min(n, 4 * max_rank)
        if m < n:
            rng = np.random.default_rng(seed)
            Xs = Xc[rng.choice(n, m, replace=False)]
        else:
            Xs = Xc
        _, s, Vt = np.linalg.svd(Xs, full_matrices=False)
        W = Vt[:r].T.astype(np.float32)
        eigvals = (s[:r] ** 2 / max(1, Xs.shape[0] - 1)).astype(np.float32)
    return {
        "mean": mean.astype(np.float32),
        "W": W[:, :r],
        "eigvals": eigvals[:r],
        "total_var": np.float32(total_var),
        "rank": r,
    }


def pca_rotate(pca: dict, X: np.ndarray, *, center: bool = False) -> np.ndarray:
    """Rotate rows of X into the PCA basis (leading ``rank`` dims).

    Distances are rotation-invariant, so when ``center`` is False we rotate
    the raw vectors (the mean cancels in o - q) — this keeps stage-2
    original-space distances and stage-1 rotated partials consistent.
    """
    X = np.asarray(X, np.float32)
    if center:
        X = X - pca["mean"]
    return X @ pca["W"]


# ---------------------------------------------------------------------------
# Random orthonormal (JL) rotation (ADSampling)
# ---------------------------------------------------------------------------


def fit_random_rotation(dim: int, *, max_rank: int = 2048, seed: int = 0) -> dict:
    """Random orthonormal projection P (D, r): leading block of a Haar matrix.

    ADSampling's estimator sqrt(D/d)*dis(P_d o, P_d q) needs the rows to be an
    orthonormal subset of a full rotation; a QR of a Gaussian matrix gives
    exactly that.
    """
    rng = np.random.default_rng(seed)
    r = min(dim, max_rank)
    G = rng.standard_normal((dim, r)).astype(np.float32)
    Q, _ = np.linalg.qr(G)  # (D, r), orthonormal columns
    return {"P": Q.astype(np.float32), "rank": r}


# ---------------------------------------------------------------------------
# Product quantization (DDCopq)
# ---------------------------------------------------------------------------


def _kmeans(X: np.ndarray, k: int, iters: int, rng) -> np.ndarray:
    n = X.shape[0]
    cent = X[rng.choice(n, size=min(k, n), replace=False)].copy()
    if cent.shape[0] < k:  # duplicate-pad degenerate case
        cent = np.concatenate([cent, cent[rng.integers(0, cent.shape[0], k - cent.shape[0])]])
    for _ in range(iters):
        d2 = (X ** 2).sum(1, keepdims=True) - 2 * X @ cent.T + (cent ** 2).sum(1)
        assign = d2.argmin(1)
        sums = np.zeros((k, X.shape[1]), np.float64)
        np.add.at(sums, assign, X)
        counts = np.bincount(assign, minlength=k).astype(np.float64)
        upd = counts > 0
        cent[upd] = (sums[upd] / counts[upd, None]).astype(np.float32)
    return cent.astype(np.float32)


def fit_pq(X: np.ndarray, *, n_sub: int = 8, n_codes: int = 256, iters: int = 8,
           train_n: int = 20000, seed: int = 0) -> dict:
    """Product quantizer: split dims into n_sub groups, k-means each.

    Returns codebooks (n_sub, n_codes, d_sub_max) zero-padded, sub-dim splits,
    and the codes for X (N, n_sub) uint8/uint16.
    """
    X = np.asarray(X, np.float32)
    n, d = X.shape
    rng = np.random.default_rng(seed)
    n_codes = min(n_codes, max(4, n // 4))
    splits = np.linspace(0, d, n_sub + 1).astype(int)
    train = X[rng.choice(n, min(train_n, n), replace=False)]
    d_sub_max = int(np.max(np.diff(splits)))
    books = np.zeros((n_sub, n_codes, d_sub_max), np.float32)
    for m in range(n_sub):
        lo, hi = splits[m], splits[m + 1]
        books[m, :, : hi - lo] = _kmeans(train[:, lo:hi], n_codes, iters, rng)
    codes = pq_encode({"books": books, "splits": splits, "n_codes": n_codes}, X)
    return {"books": books, "splits": splits, "n_codes": n_codes, "codes": codes}


def pq_encode(pq: dict, X: np.ndarray) -> np.ndarray:
    X = np.asarray(X, np.float32)
    splits, books = pq["splits"], pq["books"]
    out = np.zeros((X.shape[0], len(splits) - 1), np.uint16)
    for m in range(len(splits) - 1):
        lo, hi = splits[m], splits[m + 1]
        sub = X[:, lo:hi]
        cb = books[m, :, : hi - lo]
        d2 = (sub ** 2).sum(1, keepdims=True) - 2 * sub @ cb.T + (cb ** 2).sum(1)
        out[:, m] = d2.argmin(1)
    return out


def pq_query_lut(pq: dict, q: np.ndarray) -> np.ndarray:
    """Per-query lookup table (n_sub, n_codes) of squared sub-distances."""
    splits, books = pq["splits"], pq["books"]
    n_sub, n_codes = books.shape[0], books.shape[1]
    lut = np.zeros((n_sub, n_codes), np.float32)
    for m in range(n_sub):
        lo, hi = splits[m], splits[m + 1]
        cb = books[m, :, : hi - lo]
        lut[m] = ((cb - q[lo:hi]) ** 2).sum(1)
    return lut


def pq_adist(pq: dict, lut: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """Approximate squared distances for rows of ``codes`` given query LUT."""
    return lut[np.arange(codes.shape[1])[None, :], codes].sum(1)
