"""Streaming device DCO engine: block-fused corpus scan with a running top-k.

``core.jax_engine.two_stage_topk`` materializes a full (query_chunk, N)
estimate matrix in HBM and runs ``top_k`` over all N rows per chunk — O(N·Q)
memory and traffic that caps corpus size per device.  This engine instead
walks the rotated corpus in candidate row blocks under ``lax.scan``:

  per block   the fused ``dco_scan`` Pallas kernel computes stage-1 partial
              distances and screens against the *running* tau (its keep-count
              output is the per-block survivor tally, so no (N, Q) array
              ever leaves the loop);
  compaction  survivors are compacted on device — top-``block_capacity`` by
              estimate — and tail-completed (trailing D-d1 rotated dims);
  merge       completed rows fold into a carried per-query top-k whose k-th
              distance tightens tau for every later block — the monotone
              pruning a one-shot anchor tau cannot achieve.

Peak HBM for the estimate tile drops to O(chunk·row_block +
chunk·block_capacity), independent of N.  The running tau is certified (the
k-th best EXACT distance seen so far is always an upper bound on the true
k-th), so screening never prunes a true neighbor under a lower-bound rule;
exactness then holds whenever every screen survivor is tail-completed,
which the engine makes CHECKABLE: ``passed == survivors`` for a query
certifies that no block overflowed ``block_capacity`` (overflow = some
screen survivors were dropped by estimate-ranked compaction — the same
capacity-bounded caveat as the two-stage engine's ``capacity`` cut, at a
per-block granularity; see DESIGN.md §4 and the ``truncated_queries``
facade stat).

Decision rules: fdscan | lb | adsampling | dade | ddcres | ratio | opq.
``opq`` is DDCopq's PQ screening through the ``pq_lookup`` Pallas kernel —
the rule the two-stage engine can only serve via its exact lower-bound
fallback.

IVF probing (``probe=``): rows are laid out partition-major
(``state["row_part"]`` sorted, ``state["row_ids"]`` the permutation); blocks
whose partition span contains no probed partition get tau=-1, which the
dco_scan kernel's block-level early exit turns into skipped matmuls, and
individual rows of unprobed partitions are masked out of the keep set — a
device-side IVF probe over the same streamed layout as the flat scan.

PDX vertical layout (``dim_groups`` > 1, DESIGN.md §8): the lead dims of a
block are partitioned into contiguous dimension GROUPS — ``build_stream_blocks``
stores (n_blocks, G, block, dg) so each group is a unit-stride plane — and the
scan becomes progressive refinement: group 0 (the pure screening read) prices
every candidate row, survivors compact to a per-query top-``group_capacity``
candidate set whose +1 observer slot folds the best dropped group-0 estimate
into the exactness certificate, and later groups refine only the compacted
candidates, freezing each one whose running partial crosses the running tau.
A partial distance over any dim prefix is a valid lower bound under these
rules, so per-group freezing never needs a certificate entry; only the two
capacity cuts (R-cut and completion budget) do, and both are observed.  The
kernel path (``dco_scan_grouped``) keeps the same per-group freeze semantics
without the R-cut — dense MXU tiles with ``pl.when`` block skips are the
better trade on TPU.

On CPU (no TPU) the engine defaults to a jnp block path that is numerically
identical to the kernel semantics (same per-element arithmetic; the kernel's
mid-scan freezing only changes partials of rows that are masked anyway), so
tests and benchmarks exercise the same screening decisions the TPU runs.

With an adaptive ``core.policy.PolicyConfig`` on the config, the engine
additionally serves each block by whichever rule is winning (DESIGN.md §5):
a pre-scan seed certifies an initial tau and dispatches clearly-shifted
query chunks to a conditional-free full-scan body; all other chunks run the
screened scan with a ``PolicyState`` in the carry and a single per-block
escape that completes a block exactly when its survivors spill the
completion budget or the running cost model says screening is net-negative.
Screened blocks never drop rows under the policy, so adaptive scans are
certified by construction.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_engine import DcoEngineConfig


def _round8(v: int) -> int:
    return max(8, -(-v // 8) * 8)


def _group_plan(d1: int, groups: int):
    """Resolve a requested ``dim_groups`` against the screening width: the
    lead dims split into contiguous groups of ``ceil(d1/G)`` dims (the last
    group may be ragged; the layout zero-pads it, which adds 0 to every
    squared-distance partial).  Returns (G, dg, widths) with ``widths`` the
    logical dim count per group — idempotent, so a delta segment rebuilt
    from the main layout's group count reproduces the same split."""
    G = max(1, min(int(groups), int(d1)))
    dg = -(-d1 // G)
    G = -(-d1 // dg)
    widths = tuple(min(dg, d1 - g * dg) for g in range(G))
    return G, dg, widths


def _effective_groups(cfg: DcoEngineConfig) -> int:
    """PDX group count the engine actually honors: ``fdscan`` has no screen
    to stage and ``opq`` screens on the PQ adist rather than lead partials,
    so both force the flat (G=1) layout."""
    if cfg.kind in ("fdscan", "opq"):
        return 1
    return max(1, int(cfg.dim_groups))


def _final_scale(cfg: DcoEngineConfig, state: dict, D: int):
    """Per-rule multiplier s such that screening is ``partial * s <= tau``.
    Used for every dim-block of the kernel: intermediate partials only grow,
    so testing them against the FINAL scale is conservative (never prunes a
    row the final test would keep) and needs no per-stage eigen-mass plumbing.
    """
    d1 = cfg.d1
    if cfg.kind in ("lb", "fdscan", "ddcres", "opq"):
        return jnp.float32(1.0)    # opq screens on PQ adist, not partials
    if cfg.kind == "adsampling":
        return jnp.float32((D / d1) / (1.0 + cfg.eps0 / np.sqrt(d1)) ** 2)
    if cfg.kind == "dade":
        return 1.0 / (state["mass_d1"] * (1.0 + state["eps_d1"]) ** 2)
    if cfg.kind == "ratio":
        return jnp.float32(1.0 / max(cfg.theta, 1e-9))
    raise ValueError(cfg.kind)


def _merge_topk(best_d, best_i, new_d, new_i, k: int):
    d = jnp.concatenate([best_d, new_d], axis=1)
    i = jnp.concatenate([best_i, new_i], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(i, pos, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("row_block", "full_width", "dim_groups"))
def build_stream_blocks(state: dict, row_block: int,
                        full_width: bool = False,
                        dim_groups: int = 1) -> dict:
    """Pad the corpus to a whole number of row blocks and reshape every
    per-row array to (n_blocks, block, ...).  Pad rows carry id -1.  The
    layout depends only on the device state, ``row_block`` and
    ``dim_groups``, so callers that search repeatedly (api.backends
    .JaxBackend) build it ONCE per materialization instead of paying a
    full-corpus pad copy per query batch (N % row_block != 0 makes
    ``jnp.pad`` a real O(N*D) copy).

    ``dim_groups`` > 1 selects the PDX vertical layout (DESIGN.md §8): the
    lead dims split into contiguous groups per :func:`_group_plan` and
    ``xl`` becomes (n_blocks, G, block, dg) — dim-group-major, each group a
    unit-stride (block, dg) plane — with per-group squared norms under
    ``lsg`` (n_blocks, G, block) next to the flat ``lsq``.  A ragged last
    group zero-pads, contributing nothing to squared-distance partials.

    ``full_width=True`` keeps the block width at ``row_block`` even when the
    segment has fewer rows — required for a delta segment whose blocks are
    concatenated after a main layout of that width (append_stream_blocks)."""
    x_lead = state["x_lead"]
    n = x_lead.shape[0]
    B = row_block if full_width else min(row_block, n)
    nb = -(-n // B)
    pad = nb * B - n

    def rows(a, **kw):
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, widths, **kw).reshape(nb, B, *a.shape[1:])

    ids = state.get("row_ids")
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    xs = {
        "xl": rows(x_lead),
        "xt": rows(state["x_tail"]),
        "lsq": rows(state["lead_sq"]),
        "tsq": rows(state["tail_sq"]),
        "ids": rows(ids.astype(jnp.int32), constant_values=-1),
    }
    if "row_part" in state:     # partition-major layout for IVF probing
        xs["part"] = rows(state["row_part"].astype(jnp.int32), mode="edge")
    if "codes" in state:        # PQ codes for the opq rule
        xs["codes"] = rows(state["codes"].astype(jnp.int32))
    if dim_groups > 1:
        d1 = x_lead.shape[1]
        G, dg, _ = _group_plan(d1, dim_groups)
        if G > 1:
            xp = jnp.pad(x_lead, ((0, pad), (0, G * dg - d1)))
            xg = jnp.moveaxis(xp.reshape(nb, B, G, dg), 2, 1)
            xs["xl"] = xg                                   # (nb, G, B, dg)
            xs["lsg"] = (xg ** 2).sum(-1)                   # (nb, G, B)
    return xs


def append_stream_blocks(main: dict, delta_state: dict) -> dict:
    """Concatenate a small delta segment's blocks after a main layout.

    The delta layout is built at the MAIN block width (``full_width=True``),
    so the combined pytree is one (nb_main + nb_delta, B, ...) stack the
    engine's ``lax.scan`` walks end to end — the running tau tightened over
    the main segment carries straight into the delta blocks (and vice versa
    on later batches), which is what makes the LSM-style write path free of
    any cross-segment merge step at query time.  ``delta_state`` must carry
    ``row_ids`` (global ids of the appended rows) and the same optional keys
    (``row_part``, ``codes``) as the main layout — and it inherits the
    main layout's PDX group count (``_group_plan`` is idempotent, so the
    rebuilt split matches group-for-group)."""
    B = main["xl"].shape[-2]
    G = main["xl"].shape[1] if main["xl"].ndim == 4 else 1
    delta = build_stream_blocks(delta_state, B, full_width=True, dim_groups=G)
    missing = set(main) ^ set(delta)
    if missing:
        raise ValueError(f"delta segment layout keys differ from main: {missing}")
    return {key: jnp.concatenate([main[key], delta[key]]) for key in main}


def _adaptive(cfg: DcoEngineConfig) -> bool:
    """True when ``cfg`` carries an active adaptive policy (core.policy);
    the pure fdscan rule has nothing to fall back to."""
    return (cfg.policy is not None and cfg.policy.adaptive
            and cfg.kind != "fdscan")


def _scan_blocks(cfg: DcoEngineConfig, state, xs, ql, qt, qe, pr, B, D,
                 q_ok=None, init_tau=None, init_ewma=None, forced=False,
                 init_carry=None, return_carry=False):
    """Inner lax.scan over corpus row blocks for one query chunk.

    When ``cfg.policy`` is adaptive, the carry also holds a ``PolicyState``
    (per-query EWMA of the block survivor fraction plus the chunk's current
    mode) and each block is served through either the screened compaction
    path or a full fdscan completion — the certified fallback of DESIGN.md
    §5.  ``q_ok`` masks padding queries out of the chunk-level decision;
    ``init_tau``/``init_ewma`` carry the pre-scan seed (certified tau upper
    bound + sample pass fraction); ``forced=True`` (python-static) runs the
    dedicated conditional-free full-scan body for chunks the seed already
    placed in fallback.

    ``init_carry``/``return_carry`` (fixed, non-adaptive path only) make the
    scan RESUMABLE: the anytime driver (DESIGN.md §7) walks the corpus in
    block groups, threading the full ``(best_d, best_i, tau, surv, passed,
    dims)`` carry between jit calls so a deadline can interrupt the scan at any
    group boundary with the running top-k intact.  Resuming over block
    groups replays the exact per-block step sequence of the one-shot scan,
    so an uninterrupted grouped scan is bit-identical to it.
    """
    from repro.core.policy import pass_threshold
    from repro.kernels import ref
    from repro.kernels.ops import (_on_tpu, dco_scan_grouped_op, dco_scan_op,
                                   pq_lookup_op)

    c = ql.shape[0]
    k = cfg.k
    C = min(cfg.block_capacity, B)
    d1, Dt = ql.shape[1], qt.shape[1]
    # Mosaic requires (8, 128)-multiple tiles on real TPUs; interpret mode
    # (CPU) keeps tight tiles so tests don't pay for lane padding
    if cfg.use_kernel and _on_tpu():
        kb = dict(block_n=256, block_q=128, block_d=128)
        kb_pq = dict(block_n=128, block_q=8)
    else:
        kb = dict(block_n=min(256, _round8(B)), block_q=_round8(c),
                  block_d=min(128, _round8(d1)))
        kb_pq = dict(block_n=min(128, _round8(B)), block_q=_round8(c))
    scale = _final_scale(cfg, state, D)
    scales_arr = jnp.full((-(-d1 // kb["block_d"]),), scale, jnp.float32)
    qt_sq = (qt ** 2).sum(1)
    if cfg.kind == "ddcres":
        slack = 2.0 * cfg.m * jnp.sqrt(jnp.maximum(qe["var_d1"], 0.0))
        # a delta segment (api.backends) may carry rows with a smaller tail
        # norm than any main row; the backend threads the combined min as a
        # scalar so the Eq. 7 partial screen stays as loose as fitted
        tail_min = state.get("tail_min", state["tail_sq"]).min()

    Cp = min(C + 1, B)      # +1 slot observes the best DROPPED estimate

    # ---- PDX vertical layout (DESIGN.md §8) -------------------------------
    grouped = xs["xl"].ndim == 4
    Gr = xs["xl"].shape[1] if grouped else 1
    if grouped:
        dgp = xs["xl"].shape[-1]
        gw = tuple(min(dgp, d1 - g * dgp) for g in range(Gr))  # logical dims
        qlg = jnp.moveaxis(
            jnp.pad(ql, ((0, 0), (0, Gr * dgp - d1))).reshape(c, Gr, dgp),
            1, 0)                                              # (Gr, c, dgp)
        qgsq = (qlg ** 2).sum(-1)                              # (Gr, c)
        # jnp path: survivors of the group-0 screen compact to the per-query
        # top-R by estimate before the remaining groups are gathered — the
        # flop saving that makes progressive refinement pay off without the
        # kernel's tile-level skip.  R >= C so the completion budget never
        # tightens; the R-cut has its own observer slot (certificate).
        R = cfg.group_capacity if cfg.group_capacity > 0 else max(4 * C, 512)
        R = max(min(R, B), C)
        Rp = min(R + 1, B)
        if cfg.use_kernel:
            scales_g = jnp.full((Gr,), scale, jnp.float32)
            widths_g = jnp.asarray(gw, jnp.float32)
            kb_g = dict(block_n=kb["block_n"], block_q=kb["block_q"])

    pol = cfg.policy if _adaptive(cfg) else None
    if pol is not None:
        # cost-model threshold on the survivor fraction (static at trace
        # time): opq screens n_sub LUT dims and completes all D original
        # dims; partial rules screen d1 and complete the D - d1 tail
        if cfg.kind == "opq":
            d_screen, d_complete = float(qe["lut"].shape[1]), float(D)
        else:
            d_screen, d_complete = float(d1), float(D - d1)
        thr = pass_threshold(D, d_screen, d_complete,
                             pol.fallback_margin, pol.overhead_dims)

    def _complete_screened(best_d, best_i, tau, keep, est, partial, blk):
        # ---- on-device compaction: top-C survivors by estimate ------------
        score = jnp.where(keep, est, jnp.inf)
        neg_s, cand = jax.lax.top_k(-score, Cp)               # (c, C [+1])
        # Column C (when present) is the best estimate among rows the budget
        # DROPPED: the exactness certificate — no true neighbor was lost iff
        # the final k-th distance stays below every dropped lower bound.  It
        # is read via a masked reduce and the extra column is disabled by
        # masking, NOT by slicing: XLA CPU only rewrites the top_k sort into
        # the O(n log k) TopK custom call when it feeds a single slice, and
        # a second column slice forced a full row sort (15x slower)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, Cp), 1)
        dropped = -jnp.max(jnp.where(col == C, neg_s, -jnp.inf), -1)
        alive = (neg_s > -jnp.inf) & (col < C)
        rows = jnp.arange(c)[:, None]
        c_tail = blk["xt"][cand]                              # (c, Cp, Dt)
        tail = jnp.maximum(((c_tail - qt[:, None, :]) ** 2).sum(-1), 0.0)
        if cfg.kind == "opq":
            c_lead = blk["xl"][cand]
            exact = jnp.maximum(((c_lead - ql[:, None, :]) ** 2).sum(-1), 0.0) + tail
        else:
            exact = partial[rows, cand] + tail
        exact = jnp.where(alive, exact, jnp.inf)
        new_d, new_i = _merge_topk(best_d, best_i, exact, blk["ids"][cand], k)
        # min() keeps a tighter seeded tau alive until the running top-k
        # beats it; without a seed the k-th only decreases, so it's a no-op
        new_tau = jnp.minimum(tau, new_d[:, -1] * cfg.tau_slack)
        return (new_d, new_i, new_tau,
                alive.sum(-1).astype(jnp.int32), dropped)

    def _pdx_screen(blk, tau, tau_k, valid, rowhit):
        """Grouped progressive screen (PDX vertical layout, DESIGN.md §8).

        Group 0 — the contiguous screening read — prices every candidate
        row; survivors compact to the per-query top-``R`` by estimate with
        a +1 observer slot capturing the best estimate the R-cut DROPPED
        (``dropped0``, folded into the exactness certificate exactly like
        the completion budget's observer column); the remaining groups
        refine only the compacted candidates, freezing each one whose
        running partial crosses the running tau.  Frozen rows need no
        certificate entry: a partial over any dim prefix is a valid lower
        bound under these rules, so a row frozen above today's tau can
        never re-enter a top-k whose tau only tightens."""
        xg, lsg = blk["xl"], blk["lsg"]               # (G, B, dg), (G, B)
        ok = valid[None, :] if rowhit is None else (valid[None, :] & rowhit)
        enter = ok & (tau_k >= 0.0)[:, None]                  # (c, B)
        contrib0 = jnp.maximum(
            lsg[0][None, :] - 2.0 * qlg[0] @ xg[0].T
            + qgsq[0][:, None], 0.0)                          # (c, B)
        dims_b = enter.sum(-1).astype(jnp.float32) * jnp.float32(gw[0])
        if cfg.kind == "ddcres":
            estf = (contrib0 + blk["tsq"][None, :]
                    + qe["qtail_sq"][:, None] - slack[:, None])
            alive = (enter & (contrib0 <= tau_k[:, None])
                     & (estf <= tau[:, None]))
            rank = estf
        else:
            rank = contrib0 * scale
            alive = enter & (rank <= tau_k[:, None])
        # R-cut: same masked-observer top_k idiom as _complete_screened
        score = jnp.where(alive, rank, jnp.inf)
        neg_s, cand = jax.lax.top_k(-score, Rp)               # (c, R [+1])
        col = jax.lax.broadcasted_iota(jnp.int32, (1, Rp), 1)
        dropped0 = -jnp.max(jnp.where(col == R, neg_s, -jnp.inf), -1)
        aliveR = (neg_s > -jnp.inf) & (col < R)               # (c, Rp)
        acc = jnp.take_along_axis(contrib0, cand, axis=1)     # (c, Rp)
        for g in range(1, Gr):
            if g > 1:   # re-test the partial accumulated through group g-1
                gate = (acc <= tau_k[:, None] if cfg.kind == "ddcres"
                        else acc * scale <= tau_k[:, None])
                aliveR = aliveR & gate
            dims_b = dims_b + (aliveR.sum(-1).astype(jnp.float32)
                               * jnp.float32(gw[g]))
            xc = xg[g][cand]                                  # (c, Rp, dg)
            contrib = jnp.maximum(
                lsg[g][cand]
                - 2.0 * jnp.einsum("cd,crd->cr", qlg[g], xc)
                + qgsq[g][:, None], 0.0)
            acc = jnp.where(aliveR, acc + contrib, acc)
        if cfg.kind == "ddcres":
            est = (acc + blk["tsq"][cand] + qe["qtail_sq"][:, None]
                   - slack[:, None])
            keep = aliveR & (acc <= tau_k[:, None]) & (est <= tau[:, None])
        else:
            est = acc * scale
            keep = aliveR & (est <= tau_k[:, None])
        return cand, acc, keep, est, dropped0, dims_b

    def _complete_compacted(best_d, best_i, tau, keep, est, acc, cand,
                            dropped0, blk):
        """Exact tail completion over the PDX-compacted candidate axis: the
        same top-``C`` masked-observer compaction as _complete_screened,
        gathering block rows through ``cand``; the R-cut's observed drop
        folds into the returned certificate value."""
        CpR = min(C + 1, Rp)
        score = jnp.where(keep, est, jnp.inf)
        neg_s, sel = jax.lax.top_k(-score, CpR)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, CpR), 1)
        droppedC = -jnp.max(jnp.where(col == C, neg_s, -jnp.inf), -1)
        alive = (neg_s > -jnp.inf) & (col < C)
        rsel = jnp.take_along_axis(cand, sel, axis=1)         # (c, CpR)
        c_tail = blk["xt"][rsel]                              # (c, CpR, Dt)
        tail = jnp.maximum(((c_tail - qt[:, None, :]) ** 2).sum(-1), 0.0)
        exact = jnp.take_along_axis(acc, sel, axis=1) + tail
        exact = jnp.where(alive, exact, jnp.inf)
        new_d, new_i = _merge_topk(best_d, best_i, exact, blk["ids"][rsel], k)
        new_tau = jnp.minimum(tau, new_d[:, -1] * cfg.tau_slack)
        return (new_d, new_i, new_tau, alive.sum(-1).astype(jnp.int32),
                jnp.minimum(dropped0, droppedC))

    def _complete_all(best_d, best_i, tau, partial, ok, blk):
        # certified fallback: every candidate row is completed exactly over
        # all D dims, so nothing is dropped (dropped = +inf) and the
        # per-query exactness certificate is preserved by construction
        if partial is None:       # opq / PDX escape: lead recomputed in full
            partial = _lead_partial(blk)
        exact = partial + jnp.maximum(
            blk["tsq"][None, :] - 2.0 * qt @ blk["xt"].T + qt_sq[:, None], 0.0)
        exact = jnp.where(ok, exact, jnp.inf)
        new_d, new_i = _merge_topk(
            best_d, best_i, exact,
            jnp.broadcast_to(blk["ids"][None, :], (c, B)), k)
        new_tau = jnp.minimum(tau, new_d[:, -1] * cfg.tau_slack)
        return (new_d, new_i, new_tau, ok.sum(-1).astype(jnp.int32),
                jnp.full((c,), jnp.inf, jnp.float32))

    def step(carry, blk):
        best_d, best_i, tau, surv, passed, dims = carry
        valid = blk["ids"] >= 0                               # (B,)
        rowhit = None
        tau_k = jnp.full((c,), jnp.inf) if cfg.kind == "fdscan" else tau
        if cfg.kind == "ddcres":
            # partial <= tau_k is implied by the Eq. 7 estimate test below
            tau_k = tau + slack - qe["qtail_sq"] - tail_min
        if pr is not None:
            # block-level probe gate: partition-major rows mean each block
            # spans [pmin, pmax]; unprobed blocks get tau=-1, which the
            # kernel's pl.when(any(alive)) turns into skipped matmuls
            pmin, pmax = blk["part"].min(), blk["part"].max()
            hit = ((pr >= pmin) & (pr <= pmax)).any(-1)       # (c,)
            tau_k = jnp.where(hit, tau_k, -1.0)
            rowhit = (blk["part"][None, :, None] == pr[:, None, :]).any(-1)
        okm = valid[None, :] if rowhit is None else (valid[None, :] & rowhit)
        n_okq = okm.sum(-1).astype(jnp.float32)               # (c,)

        if grouped and not cfg.use_kernel:
            # PDX progressive refinement on the jnp path (DESIGN.md §8)
            cand, acc, keepR, estR, dropped0, dims_scr = _pdx_screen(
                blk, tau, tau_k, valid, rowhit)
            passed_b = keepR.sum(-1).astype(jnp.int32)
            new_d, new_i, new_tau, completed, dropped = _complete_compacted(
                best_d, best_i, tau, keepR, estR, acc, cand, dropped0, blk)
            dims_b = dims_scr + completed.astype(jnp.float32) * (D - d1)
            return ((new_d, new_i, new_tau, surv + completed,
                     passed + passed_b, dims + dims_b), dropped)

        passed_b = None
        if cfg.kind == "opq":
            if cfg.use_kernel:
                adist = pq_lookup_op(blk["codes"], qe["lut"], **kb_pq)
            else:
                adist = ref.pq_lookup_ref(blk["codes"], qe["lut"])
            est = adist.T / cfg.theta                         # (c, B)
            keep = (est <= tau[:, None]) & valid[None, :]
            partial = None
            dims_scr = n_okq * float(qe["lut"].shape[1])
        elif cfg.use_kernel and grouped:
            nvalid = valid.sum().astype(jnp.int32)
            p, kp, cnt, ad = dco_scan_grouped_op(
                blk["xl"], qlg, tau_k, scales_g, widths_g, nvalid, **kb_g)
            partial, keep = p.T, kp.T.astype(bool)            # (c, B)
            est = partial * scale
            passed_b = cnt.sum(0)       # the kernel's per-block keep counts
            dims_scr = ad.sum(0)        # measured dims entered per query
        elif cfg.use_kernel:
            nvalid = valid.sum().astype(jnp.int32)
            p, kp, cnt, ad = dco_scan_op(blk["xl"], ql, tau_k, scales_arr,
                                         nvalid, **kb)
            partial, keep = p.T, kp.T.astype(bool)            # (c, B)
            est = partial * scale
            passed_b = cnt.sum(0)       # the kernel's per-block keep counts
            dims_scr = ad.sum(0)        # measured dims entered per query
        else:
            partial = jnp.maximum(
                blk["lsq"][None, :] - 2.0 * ql @ blk["xl"].T
                + (ql ** 2).sum(1)[:, None], 0.0)             # (c, B)
            est = partial * scale
            keep = (est <= tau_k[:, None]) & valid[None, :]
            # flat jnp screen reads all d1 lead dims of every candidate row
            # of a probed block (tau_k < 0 marks a block the probe skips)
            dims_scr = jnp.where(tau_k >= 0.0, n_okq, 0.0) * float(d1)
        if cfg.kind == "ddcres":
            # full-distance estimate (core.methods Eq. 7) refines the
            # conservative in-kernel partial screen and drives compaction
            est = (partial + blk["tsq"][None, :]
                   + qe["qtail_sq"][:, None] - slack[:, None])
            keep = keep & (est <= tau[:, None])
            passed_b = None
        if rowhit is not None:
            keep = keep & rowhit
            passed_b = None
        if passed_b is None:
            passed_b = keep.sum(-1).astype(jnp.int32)

        if cfg.kind == "fdscan":
            exact = partial + jnp.maximum(
                blk["tsq"][None, :] - 2.0 * qt @ blk["xt"].T
                + qt_sq[:, None], 0.0)
            ok = okm
            exact = jnp.where(ok, exact, jnp.inf)
            new_d, new_i = _merge_topk(
                best_d, best_i, exact,
                jnp.broadcast_to(blk["ids"][None, :], (c, B)), k)
            n_done = ok.sum(-1).astype(jnp.int32)
            new_tau = jnp.full((c,), jnp.inf)
            return ((new_d, new_i, new_tau, surv + n_done, passed + n_done,
                     dims + n_okq * float(D)),
                    jnp.full((c,), jnp.inf))

        new_d, new_i, new_tau, completed, dropped = _complete_screened(
            best_d, best_i, tau, keep, est, partial, blk)
        comp_w = float(D if cfg.kind == "opq" else D - d1)
        dims_b = dims_scr + completed.astype(jnp.float32) * comp_w
        return ((new_d, new_i, new_tau, surv + completed,
                 passed + passed_b, dims + dims_b), dropped)

    # ---- adaptive serving (DESIGN.md §5) ----------------------------------
    # One lax.cond per block whose branches are SELF-CONTAINED (each computes
    # its own stage-1 partial): a conditional boundary through shared big
    # intermediates forces XLA to materialize them and breaks the fused
    # screen->compact chain, which measured 25-45% on CPU.  The mode is
    # decided from history — the seeded pre-scan pass fraction plus every
    # earlier block's telemetry — and the screened branch carries a rare
    # recompute-from-scratch SPILL escape (survivors over block_capacity
    # complete the block exactly), so screened blocks never drop rows and
    # adaptive scans are certified by construction.
    q_okm = jnp.ones((c,), bool) if q_ok is None else q_ok

    def _lead_partial(blk):
        xl = blk["xl"]
        if xl.ndim == 3:            # PDX grouped layout: sum per-group reads
            acc = jnp.zeros((c, xl.shape[-2]), jnp.float32)
            for g in range(Gr):
                acc = acc + jnp.maximum(
                    blk["lsg"][g][None, :] - 2.0 * qlg[g] @ xl[g].T
                    + qgsq[g][:, None], 0.0)
            return acc
        return jnp.maximum(
            blk["lsq"][None, :] - 2.0 * ql @ xl.T
            + (ql ** 2).sum(1)[:, None], 0.0)                 # (c, B)

    def _screen_of(partial, blk, tau, ok):
        """(est, keep) for this block under the running tau; ``partial`` is
        the lead partial (None for opq, which screens on the PQ adist)."""
        if cfg.kind == "opq":
            if cfg.use_kernel:
                adist = pq_lookup_op(blk["codes"], qe["lut"], **kb_pq)
            else:
                adist = ref.pq_lookup_ref(blk["codes"], qe["lut"])
            est = adist.T / cfg.theta
        elif cfg.kind == "ddcres":
            est = (partial + blk["tsq"][None, :]
                   + qe["qtail_sq"][:, None] - slack[:, None])
        else:
            est = partial * scale
        return est, (est <= tau[:, None]) & ok

    def step_adaptive(carry, blk):
        # ONE conditional per block: the screened body runs fused exactly
        # like the fixed engine, then an ESCAPE serves the block fully when
        # (a) the screen spilled its completion budget — the capacity cut
        # would drop rows, so the exact completion keeps the scan CERTIFIED
        # BY CONSTRUCTION — or (b) the running cost model says screening is
        # net-negative (mode, with hysteresis).  The escape recomputes the
        # lead from scratch so the common no-escape path stays fusible.
        best_d, best_i, tau, surv, passed, dims, ps = carry
        valid = blk["ids"] >= 0
        rowhit = None
        if pr is not None:
            rowhit = (blk["part"][None, :, None] == pr[:, None, :]).any(-1)
        ok = (jnp.broadcast_to(valid[None, :], (c, B)) if rowhit is None
              else (valid[None, :] & rowhit))
        n_ok = ok.sum(-1).astype(jnp.int32)
        nokf = n_ok.astype(jnp.float32)

        if grouped:
            # PDX under the policy: the R-cut joins the spill gate — a cut
            # that dropped ANY alive row escapes to the exact completion, so
            # screened blocks still never drop rows and the adaptive scan
            # stays certified by construction, now per dim group.  The
            # escape recomputes the full lead (group-aware _lead_partial) so
            # the common screened path keeps only (c, R) operands across the
            # cond boundary.
            tau_ka = (tau + slack - qe["qtail_sq"] - tail_min
                      if cfg.kind == "ddcres" else tau)
            cand, acc, keepR, estR, dropped0, dims_scr = _pdx_screen(
                blk, tau, tau_ka, valid, rowhit)
            passed_b = keepR.sum(-1).astype(jnp.int32)
            spill = (q_okm & ((passed_b > C) | ~jnp.isinf(dropped0))).any()
            esc = spill | ps["mode"]
            new_d, new_i, new_tau, completed, dropped = jax.lax.cond(
                esc,
                lambda: _complete_all(best_d, best_i, tau, None, ok, blk),
                lambda: _complete_compacted(best_d, best_i, tau, keepR, estR,
                                            acc, cand, dropped0, blk))
            dims_b = jnp.where(
                esc, dims_scr + nokf * float(D),
                dims_scr + completed.astype(jnp.float32) * float(D - d1))
        else:
            partial = None if cfg.kind == "opq" else _lead_partial(blk)
            est, keep = _screen_of(partial, blk, tau, ok)
            passed_b = keep.sum(-1).astype(jnp.int32)
            spill = (q_okm & (passed_b > C)).any()
            esc = spill | ps["mode"]
            # both completions live INSIDE the cond so an escaped block
            # (steady fallback, or a spill) never pays the screened
            # compaction; the escape reuses the stage-1 partial, which
            # crosses the boundary anyway as an operand of the screened
            # branch
            new_d, new_i, new_tau, completed, dropped = jax.lax.cond(
                esc,
                lambda: _complete_all(best_d, best_i, tau, partial, ok, blk),
                lambda: _complete_screened(best_d, best_i, tau, keep, est,
                                           partial, blk))
            dims_b = jnp.where(
                esc, nokf * (d_screen + d_complete),
                nokf * d_screen
                + completed.astype(jnp.float32) * d_complete)

        # policy evidence.  A SPILL means screening lost this block
        # outright (it still paid a full completion): full-strength
        # evidence, so chronic spills flip the chunk into steady fallback.
        # Other blocks contribute the real screen fraction, which keeps
        # recovery possible.  Cold non-spill blocks carry no signal
        # (tau=inf makes the screen trivial).
        frac = passed_b.astype(jnp.float32) / jnp.maximum(n_ok, 1)
        warm = (n_ok > 0) & ~jnp.isinf(tau)
        spill_evt = spill & ~ps["mode"]
        obs = (warm | spill_evt) & (n_ok > 0)
        sig = jnp.where(spill_evt, 1.0, frac)
        a = jnp.float32(pol.ewma_alpha)
        new_ewma = jnp.where(obs & (ps["n"] > 0),
                             a * sig + (1.0 - a) * ps["ewma"], ps["ewma"])
        new_ewma = jnp.where(obs & (ps["n"] == 0), sig, new_ewma)
        new_n = ps["n"] + obs
        # next block's mode: a chunk falls back when ANY member query's
        # model says screening is net-negative (correctness-first; batch
        # OOD queries together so they don't drag ID chunks), and recovers
        # only once every member is back under the hysteresis band
        live = q_okm & (new_n > 0)
        want = (live & (new_ewma > thr)).any()
        stay = (live & (new_ewma > thr * pol.hysteresis)).any()
        next_mode = jnp.where(ps["mode"], stay, want)
        # an escaped block paid the screen bookkeeping on top of the full
        # completion; a screened block saves the unscanned tail
        saved_blk = jnp.where(
            esc, -(d_screen + pol.overhead_dims) * n_ok,
            (n_ok - completed) * d_complete - pol.overhead_dims * n_ok)
        new_ps = {
            "ewma": new_ewma, "n": new_n, "mode": next_mode,
            "fb": ps["fb"] + esc.astype(jnp.int32),
            "saved": ps["saved"] + 2.0 * saved_blk,
        }
        return ((new_d, new_i, new_tau, surv + completed, passed + passed_b,
                 dims + dims_b, new_ps), (dropped, esc.astype(jnp.float32)))

    init = (jnp.full((c, k), jnp.inf, jnp.float32),
            jnp.full((c, k), -1, jnp.int32),
            jnp.full((c,), jnp.inf, jnp.float32),
            jnp.zeros((c,), jnp.int32), jnp.zeros((c,), jnp.int32),
            jnp.zeros((c,), jnp.float32))
    if pol is None:
        if init_carry is not None:
            init = init_carry
        carry, dropped = jax.lax.scan(step, init, xs)
        if return_carry:
            return carry, dropped.min(0)
        d, i, _, surv, passed, dims = carry
        return d, i, surv, passed, dropped.min(0), dims

    nb = xs["xl"].shape[0]
    if init_tau is None:
        init_tau = jnp.full((c,), jnp.inf, jnp.float32)
    if init_ewma is None:
        init_ewma = jnp.zeros((c,), jnp.float32)
        init_n = jnp.zeros((c,), jnp.int32)
    elif cfg.kind == "opq":         # opq seed evidence needs adist: neutral
        init_ewma = jnp.zeros((c,), jnp.float32)
        init_n = jnp.zeros((c,), jnp.int32)
    else:
        init_n = jnp.ones((c,), jnp.int32)
    init = init[:2] + (init_tau,) + init[3:]

    if forced:
        # the whole chunk starts in fallback (the seed already said
        # screening is net-negative): serve it with a dedicated fused body —
        # the switching machinery never enters this graph, so a shifted
        # chunk costs ~a plain full scan plus the seed
        def step_full(carry, blk):
            best_d, best_i, tau, surv, passed, dims = carry
            valid = blk["ids"] >= 0
            if pr is None:
                ok = jnp.broadcast_to(valid[None, :], (c, B))
            else:
                rowhit = (blk["part"][None, :, None] == pr[:, None, :]).any(-1)
                ok = valid[None, :] & rowhit
            exact = _lead_partial(blk) + jnp.maximum(
                blk["tsq"][None, :] - 2.0 * qt @ blk["xt"].T
                + qt_sq[:, None], 0.0)
            exact = jnp.where(ok, exact, jnp.inf)
            nd, ni = _merge_topk(
                best_d, best_i, exact,
                jnp.broadcast_to(blk["ids"][None, :], (c, B)), k)
            ntau = jnp.minimum(tau, nd[:, -1] * cfg.tau_slack)
            n_ok = ok.sum(-1).astype(jnp.int32)
            return (nd, ni, ntau, surv + n_ok, passed + n_ok,
                    dims + n_ok.astype(jnp.float32) * float(D)), None

        (d, i, _, surv, passed, dims), _ = jax.lax.scan(step_full, init, xs)
        report = {"fb": jnp.full((c,), nb, jnp.int32),
                  "saved": jnp.zeros((c,), jnp.float32),
                  "timeline": jnp.ones((nb,), jnp.float32)}
        return (d, i, surv, passed, jnp.full((c,), jnp.inf, jnp.float32),
                dims, report)

    ini = init + ({"ewma": init_ewma, "n": init_n,
                   "mode": jnp.asarray(False),
                   "fb": jnp.asarray(0, jnp.int32),
                   "saved": jnp.zeros((c,), jnp.float32)},)
    (d, i, _, surv, passed, dims, ps), (dropped, modes) = jax.lax.scan(
        step_adaptive, ini, xs)
    report = {"fb": jnp.broadcast_to(ps["fb"], (c,)),
              "saved": ps["saved"], "timeline": modes}
    return d, i, surv, passed, dropped.min(0), dims, report


@functools.partial(jax.jit, static_argnames=("cfg",))
def _stream_topk_padded(state: dict, xs: dict, q_lead, q_tail, q_extra: dict,
                        probe, cfg: DcoEngineConfig):
    d1 = q_lead.shape[1]
    D = d1 + q_tail.shape[1]
    B = xs["xl"].shape[-2]
    nq = q_lead.shape[0]
    c = min(cfg.query_chunk, nq)
    ql = q_lead.reshape(nq // c, c, -1)
    qt = q_tail.reshape(nq // c, c, -1)
    qe = {key: v.reshape(nq // c, c, *v.shape[1:]) for key, v in q_extra.items()}
    pr = None if probe is None else probe.reshape(nq // c, c, -1)

    def one_chunk(args):
        cql, cqt, cqe, cpr = args
        return _scan_blocks(cfg, state, xs, cql, cqt, cqe, cpr, B, D)

    d, i, surv, passed, dmin, dims = jax.lax.map(one_chunk, (ql, qt, qe, pr))
    k = cfg.k
    return (d.reshape(nq, k), i.reshape(nq, k),
            surv.reshape(nq), passed.reshape(nq), dmin.reshape(nq),
            dims.reshape(nq))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _anytime_group(state: dict, xs: dict, q_lead, q_tail, q_extra: dict,
                   probe, carry, cfg: DcoEngineConfig):
    """Resume the fixed streaming scan over ONE group of corpus blocks.

    ``carry`` is the whole padded batch's running state —
    ``(best_d (nq,k), best_i (nq,k), tau (nq,), surv (nq,), passed (nq,),
    dims (nq,), dropped_min (nq,))`` — threaded between jit calls by the
    anytime driver
    in :func:`stream_topk` (DESIGN.md §7).  Each call advances every query
    chunk by this group's blocks and returns the updated carry; the group
    boundary is the python-level point where the deadline is checked."""
    D = q_lead.shape[1] + q_tail.shape[1]
    B = xs["xl"].shape[-2]
    nq = q_lead.shape[0]
    c = min(cfg.query_chunk, nq)
    ql = q_lead.reshape(nq // c, c, -1)
    qt = q_tail.reshape(nq // c, c, -1)
    qe = {key: v.reshape(nq // c, c, *v.shape[1:]) for key, v in q_extra.items()}
    pr = None if probe is None else probe.reshape(nq // c, c, -1)
    cc = jax.tree_util.tree_map(
        lambda a: a.reshape(nq // c, c, *a.shape[1:]), carry)

    def one_chunk(args):
        cql, cqt, cqe, cpr, ccar = args
        new, dmin_g = _scan_blocks(cfg, state, xs, cql, cqt, cqe, cpr, B, D,
                                   init_carry=ccar[:6], return_carry=True)
        return new + (jnp.minimum(ccar[6], dmin_g),)

    out = jax.lax.map(one_chunk, (ql, qt, qe, pr, cc))
    return tuple(a.reshape(nq, *a.shape[2:]) for a in out)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _seed_eval(state: dict, xs: dict, q_lead, q_tail, q_extra: dict,
               cfg: DcoEngineConfig):
    """Pre-scan seed for the adaptive policy, over the whole padded batch.

    The k-th exact distance over a row sample upper-bounds the true k-th
    (CERTIFIED: screening against it can never prune a true neighbor under
    a lower-bound rule), and the sample's pass fraction against that tau
    estimates the corpus survivor fraction before any block is scanned.
    Expected pass rate vs the seeded tau is ~k/S per row, so S keeps early
    blocks under the spill gate (k/S * row_block << block_capacity).
    Returns (tau0 (nq,), ewma0 (nq,)).
    """
    B = xs["xl"].shape[-2]
    D = q_lead.shape[1] + q_tail.shape[1]
    S = min(1024, B)
    ql, qt = q_lead, q_tail
    sid = xs["ids"][0, :S]
    svalid = sid[None, :] >= 0
    xl0 = xs["xl"][0]
    if xl0.ndim == 3:               # PDX grouped layout (DESIGN.md §8)
        Gg, dgp = xl0.shape[0], xl0.shape[2]
        d1 = ql.shape[1]
        qg = jnp.moveaxis(
            jnp.pad(ql, ((0, 0), (0, Gg * dgp - d1))).reshape(
                ql.shape[0], Gg, dgp), 1, 0)
        lead_s = jnp.zeros((ql.shape[0], S), jnp.float32)
        for g in range(Gg):
            lead_s = lead_s + jnp.maximum(
                xs["lsg"][0][g, :S][None, :] - 2.0 * qg[g] @ xl0[g, :S].T
                + (qg[g] ** 2).sum(1)[:, None], 0.0)
    else:
        lead_s = jnp.maximum(
            xs["lsq"][0, :S][None, :] - 2.0 * ql @ xl0[:S].T
            + (ql ** 2).sum(1)[:, None], 0.0)
    ex = lead_s + jnp.maximum(
        xs["tsq"][0, :S][None, :] - 2.0 * qt @ xs["xt"][0, :S].T
        + (qt ** 2).sum(1)[:, None], 0.0)
    ex = jnp.where(svalid, ex, jnp.inf)
    neg, _ = jax.lax.top_k(-ex, min(cfg.k, S))
    tau0 = -neg[:, -1] * cfg.tau_slack
    if cfg.kind == "opq":           # opq evidence needs adist: stay neutral
        return tau0, jnp.zeros(ql.shape[0], jnp.float32)
    if cfg.kind == "ddcres":
        slack = 2.0 * cfg.m * jnp.sqrt(jnp.maximum(q_extra["var_d1"], 0.0))
        est_s = (lead_s + xs["tsq"][0, :S][None, :]
                 + q_extra["qtail_sq"][:, None] - slack[:, None])
    else:
        est_s = lead_s * _final_scale(cfg, state, D)
    pass_s = ((est_s <= tau0[:, None]) & svalid).sum(-1)
    ewma0 = (pass_s / jnp.maximum(svalid.sum(-1), 1)).astype(jnp.float32)
    return tau0, ewma0


@functools.partial(jax.jit, static_argnames=("cfg", "forced"))
def _stream_chunk(state: dict, xs: dict, ql, qt, qe: dict, pr, qv, tau0, ew0,
                  cfg: DcoEngineConfig, forced: bool):
    """One query chunk through the adaptive engine (forced=True: the
    conditional-free full-scan body for chunks the seed put in fallback)."""
    D = ql.shape[1] + qt.shape[1]
    B = xs["xl"].shape[-2]
    return _scan_blocks(cfg, state, xs, ql, qt, qe, pr, B, D, q_ok=qv,
                        init_tau=tau0, init_ewma=ew0, forced=forced)


def _anytime_topk(state: dict, blocks: dict, q_lead, q_tail, q_extra: dict,
                  probe, cfg: DcoEngineConfig, nq: int, deadline_ts: float,
                  block_group: int):
    """Deadline-aware anytime driver (DESIGN.md §7): python loop over block
    groups, one host sync + wall check per group, early exit with the
    running top-k on expiry.  Returns the 6-tuple of :func:`stream_topk`
    plus ``coverage`` (fraction of corpus blocks scanned)."""
    from repro.testing import faults

    fp = faults.active()
    nqp, k = q_lead.shape[0], cfg.k
    carry = (jnp.full((nqp, k), jnp.inf, jnp.float32),
             jnp.full((nqp, k), -1, jnp.int32),
             jnp.full((nqp,), jnp.inf, jnp.float32),
             jnp.zeros((nqp,), jnp.int32),
             jnp.zeros((nqp,), jnp.int32),
             jnp.zeros((nqp,), jnp.float32),
             jnp.full((nqp,), jnp.inf, jnp.float32))
    nb = blocks["xl"].shape[0]
    G = max(1, int(block_group))
    done = 0
    while done < nb:
        g = min(G, nb - done)
        xs_g = {key: v[done:done + g] for key, v in blocks.items()}
        carry = _anytime_group(state, xs_g, q_lead, q_tail, q_extra, probe,
                               carry, cfg)
        done += g
        # the sync that makes the wall check honest: without it the python
        # loop races ahead of the async device queue and the deadline only
        # fires after every group has already been dispatched
        jax.block_until_ready(carry[0])
        faults.sleep_block(fp)
        if time.monotonic() > deadline_ts:
            break
    d, i, _, surv, passed, dims, dmin = carry
    return (d[:nq], i[:nq], surv[:nq], passed[:nq], dmin[:nq], dims[:nq],
            done / nb)


def stream_topk(state: dict, q_lead, q_tail, cfg: DcoEngineConfig,
                q_extra: dict | None = None, probe=None, blocks=None,
                deadline_ts: float | None = None, block_group: int = 8):
    """Streaming top-k over the local corpus for a batch of rotated queries.

    q_lead (Q, d1), q_tail (Q, D - d1).  ``state`` is a
    ``jax_engine.build_device_state`` export, optionally extended with
    ``row_ids`` (original ids when rows were permuted), ``row_part`` +
    ``probe`` (Q, nprobe) for IVF probing, and ``codes`` for the opq rule.
    ``blocks`` is an optional pre-built :func:`build_stream_blocks` layout
    (built here when absent — repeat callers should cache it; it must have
    been built with the group count :func:`_effective_groups` resolves for
    ``cfg``).  Ragged batches pad to a whole number of query chunks; N need
    not divide ``cfg.row_block``.  Returns (dists_sq (Q, k), ids (Q, k),
    survivors (Q,) rows tail-completed, passed (Q,) rows passing the screen,
    dropped_min_est (Q,) the smallest estimate among screen survivors any
    capacity cut dropped (+inf when nothing was dropped), dims_read (Q,)
    total dimensions the scan touched for the query — screening reads plus
    completed tails — the telemetry behind the facade's ``dims_read_mean``).
    ``dropped_min_est[q] > dists_sq[q, k-1]`` CERTIFIES exactness for
    lower-bound rules: every dropped row's lower bound exceeds the returned
    k-th distance, so no true neighbor was truncated.  A failed certificate
    means block_capacity should be raised (or row_block shrunk).

    ``cfg.dim_groups`` > 1 serves the scan from the PDX vertical layout
    (DESIGN.md §8): per-group progressive refinement with the R-cut's
    observer folded into ``dropped_min_est``, so the same certificate
    inequality covers group-level drops.  fdscan and opq force G=1.

    When ``cfg.policy`` is an adaptive ``core.policy.PolicyConfig`` the
    engine serves blocks adaptively (DESIGN.md §5) and appends a seventh
    return value, a report dict with per-query ``fallback_blocks`` /
    ``est_saved_flops`` and a per-block ``rule_timeline`` (fraction of query
    chunks served by fdscan).  Adaptive mode forces ``use_kernel=False`` for
    the dco_scan stage: the Pallas kernel freezes pruned rows mid-block, so
    its partials cannot be reused by the fallback branch's full completion
    (the pq_lookup path is unaffected).  A policy with
    ``force_fallback=True`` (the guardrail breaker's demotion, DESIGN.md
    §9) skips the seed entirely and serves EVERY chunk by the dedicated
    full-scan body — exact and certified by construction.

    ``deadline_ts`` (absolute ``time.monotonic()`` timestamp) arms ANYTIME
    mode (DESIGN.md §7): the corpus is walked in groups of ``block_group``
    row blocks, the running carry is synced and the wall clock checked at
    every group boundary, and on expiry the running top-k is returned as a
    partial result.  At least one group is always scanned.  The return
    gains a seventh element, ``coverage`` — the fraction of corpus blocks
    scanned (1.0 = the full scan, in which case results are bit-identical
    to the non-deadline path: the grouped scan replays the exact same
    per-block step sequence).  Queries with ``coverage < 1`` must be
    treated as UNCERTIFIED regardless of ``dropped_min_est`` (unscanned
    blocks may hold true neighbors); the facade's ``uncertified_mask``
    encodes this.  Anytime mode serves the fixed scan only — the backend
    strips an adaptive policy before a deadline call.
    """
    q_extra = dict(q_extra or {})
    adaptive = _adaptive(cfg)
    # adaptive mode forces the jnp dco_scan path (the kernel freezes pruned
    # rows mid-block, so its partials can't feed an escape's full
    # completion); opq screens via pq_lookup, whose adist is valid for all
    # rows, so it keeps its kernel
    force_jnp = adaptive and cfg.kind != "opq"
    if force_jnp and cfg.use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=False)
    if cfg.use_kernel is None:
        from repro.kernels.ops import _on_tpu
        cfg = dataclasses.replace(cfg, use_kernel=False if force_jnp
                                  else _on_tpu())
    ge = _effective_groups(cfg)
    if blocks is None:
        blocks = build_stream_blocks(state, cfg.row_block, dim_groups=ge)
    gb = blocks["xl"].shape[1] if blocks["xl"].ndim == 4 else 1
    gp = _group_plan(q_lead.shape[1], ge)[0] if ge > 1 else 1
    if gb != gp:
        raise ValueError(
            f"cached blocks layout has {gb} dim group(s) but cfg resolves "
            f"to {gp}: rebuild build_stream_blocks with dim_groups={ge}")
    nq = q_lead.shape[0]
    if nq == 0:
        raise ValueError("stream_topk needs at least one query")
    c = min(cfg.query_chunk, nq)
    pad = (-nq) % c
    if pad:
        q_lead = jnp.pad(q_lead, ((0, pad), (0, 0)))
        q_tail = jnp.pad(q_tail, ((0, pad), (0, 0)))
        q_extra = {key: jnp.pad(v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
                   for key, v in q_extra.items()}
        if probe is not None:
            probe = jnp.pad(probe, ((0, pad), (0, 0)))
    if deadline_ts is not None:
        if adaptive:
            raise ValueError(
                "anytime deadlines run the fixed streaming scan — strip the "
                "adaptive policy from cfg before a deadline call "
                "(DESIGN.md §7)")
        return _anytime_topk(state, blocks, q_lead, q_tail, q_extra, probe,
                             cfg, nq, deadline_ts, block_group)
    if not adaptive:
        d, i, s, p, dm, dr = _stream_topk_padded(state, blocks, q_lead,
                                                 q_tail, q_extra, probe, cfg)
        return d[:nq], i[:nq], s[:nq], p[:nq], dm[:nq], dr[:nq]

    # ---- adaptive orchestration (DESIGN.md §5) ----------------------------
    # Per-chunk python dispatch: the seed's pass fraction decides, per query
    # chunk and BEFORE any block is scanned, whether the chunk enters the
    # switching scan or the dedicated conditional-free full-scan body.  The
    # decision is one tiny host sync per batch; keeping it out of the jitted
    # graph avoids a whole-scan lax.cond, which measurably taxes the
    # executed branch on CPU.  (IVF probing gets no seed — sampled rows may
    # not be probe candidates — so probed chunks always run the switching
    # scan, whose spill gate keeps them certified.)
    from repro.core.policy import pass_threshold
    nqp = q_lead.shape[0]
    nchunks = nqp // c
    q_valid = jnp.arange(nqp) < nq
    if cfg.policy.force_fallback:
        # guardrail demotion (DESIGN.md §9): every chunk runs the dedicated
        # conditional-free full-scan body — certified by construction, no
        # seed pass needed (works for flat and IVF-probed scans alike)
        tau0 = ew0 = None
        chunk_full = np.ones(nchunks, bool)
    elif probe is None:
        tau0, ew0 = _seed_eval(state, blocks, q_lead, q_tail, q_extra, cfg)
        D = q_lead.shape[1] + q_tail.shape[1]
        if cfg.kind == "opq":
            d_screen, d_complete = float(q_extra["lut"].shape[1]), float(D)
        else:
            d_screen, d_complete = float(q_lead.shape[1]), float(D - q_lead.shape[1])
        thr = pass_threshold(D, d_screen, d_complete,
                             cfg.policy.fallback_margin,
                             cfg.policy.overhead_dims)
        chunk_full = np.asarray(
            (ew0 > thr) & q_valid).reshape(nchunks, c).any(1)
    else:
        tau0 = ew0 = None
        chunk_full = np.zeros(nchunks, bool)
    outs = []
    for ci in range(nchunks):
        sl = slice(ci * c, (ci + 1) * c)
        outs.append(_stream_chunk(
            state, blocks, q_lead[sl], q_tail[sl],
            {key: v[sl] for key, v in q_extra.items()},
            None if probe is None else probe[sl], q_valid[sl],
            None if tau0 is None else tau0[sl],
            None if ew0 is None else ew0[sl],
            cfg, bool(chunk_full[ci])))
    if nchunks == 1:
        d, i, s, p, dm, dr, rep = outs[0]
    else:
        d, i, s, p, dm, dr = (jnp.concatenate([o[j] for o in outs])
                              for j in range(6))
        rep = {key: jnp.concatenate([o[6][key] for o in outs])
               for key in ("fb", "saved")}
        rep["timeline"] = jnp.stack([o[6]["timeline"] for o in outs]).mean(0)
    report = {"fallback_blocks": rep["fb"][:nq],
              "est_saved_flops": rep["saved"][:nq],
              "rule_timeline": jnp.atleast_1d(rep["timeline"])}
    return d[:nq], i[:nq], s[:nq], p[:nq], dm[:nq], dr[:nq], report
