"""Streaming device DCO engine: block-fused corpus scan with a running top-k.

``core.jax_engine.two_stage_topk`` materializes a full (query_chunk, N)
estimate matrix in HBM and runs ``top_k`` over all N rows per chunk — O(N·Q)
memory and traffic that caps corpus size per device.  This engine instead
walks the rotated corpus in candidate row blocks under ``lax.scan``:

  per block   the fused ``dco_scan`` Pallas kernel computes stage-1 partial
              distances and screens against the *running* tau (its keep-count
              output is the per-block survivor tally, so no (N, Q) array
              ever leaves the loop);
  compaction  survivors are compacted on device — top-``block_capacity`` by
              estimate — and tail-completed (trailing D-d1 rotated dims);
  merge       completed rows fold into a carried per-query top-k whose k-th
              distance tightens tau for every later block — the monotone
              pruning a one-shot anchor tau cannot achieve.

Peak HBM for the estimate tile drops to O(chunk·row_block +
chunk·block_capacity), independent of N.  The running tau is certified (the
k-th best EXACT distance seen so far is always an upper bound on the true
k-th), so screening never prunes a true neighbor under a lower-bound rule;
exactness then holds whenever every screen survivor is tail-completed,
which the engine makes CHECKABLE: ``passed == survivors`` for a query
certifies that no block overflowed ``block_capacity`` (overflow = some
screen survivors were dropped by estimate-ranked compaction — the same
capacity-bounded caveat as the two-stage engine's ``capacity`` cut, at a
per-block granularity; see DESIGN.md §4 and the ``truncated_queries``
facade stat).

Decision rules: fdscan | lb | adsampling | dade | ddcres | ratio | opq.
``opq`` is DDCopq's PQ screening through the ``pq_lookup`` Pallas kernel —
the rule the two-stage engine can only serve via its exact lower-bound
fallback.

IVF probing (``probe=``): rows are laid out partition-major
(``state["row_part"]`` sorted, ``state["row_ids"]`` the permutation); blocks
whose partition span contains no probed partition get tau=-1, which the
dco_scan kernel's block-level early exit turns into skipped matmuls, and
individual rows of unprobed partitions are masked out of the keep set — a
device-side IVF probe over the same streamed layout as the flat scan.

On CPU (no TPU) the engine defaults to a jnp block path that is numerically
identical to the kernel semantics (same per-element arithmetic; the kernel's
mid-scan freezing only changes partials of rows that are masked anyway), so
tests and benchmarks exercise the same screening decisions the TPU runs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_engine import DcoEngineConfig


def _round8(v: int) -> int:
    return max(8, -(-v // 8) * 8)


def _final_scale(cfg: DcoEngineConfig, state: dict, D: int):
    """Per-rule multiplier s such that screening is ``partial * s <= tau``.
    Used for every dim-block of the kernel: intermediate partials only grow,
    so testing them against the FINAL scale is conservative (never prunes a
    row the final test would keep) and needs no per-stage eigen-mass plumbing.
    """
    d1 = cfg.d1
    if cfg.kind in ("lb", "fdscan", "ddcres", "opq"):
        return jnp.float32(1.0)    # opq screens on PQ adist, not partials
    if cfg.kind == "adsampling":
        return jnp.float32((D / d1) / (1.0 + cfg.eps0 / np.sqrt(d1)) ** 2)
    if cfg.kind == "dade":
        return 1.0 / (state["mass_d1"] * (1.0 + state["eps_d1"]) ** 2)
    if cfg.kind == "ratio":
        return jnp.float32(1.0 / max(cfg.theta, 1e-9))
    raise ValueError(cfg.kind)


def _merge_topk(best_d, best_i, new_d, new_i, k: int):
    d = jnp.concatenate([best_d, new_d], axis=1)
    i = jnp.concatenate([best_i, new_i], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(i, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("row_block",))
def build_stream_blocks(state: dict, row_block: int) -> dict:
    """Pad the corpus to a whole number of row blocks and reshape every
    per-row array to (n_blocks, block, ...).  Pad rows carry id -1.  The
    layout depends only on the device state and ``row_block``, so callers
    that search repeatedly (api.backends.JaxBackend) build it ONCE per
    materialization instead of paying a full-corpus pad copy per query
    batch (N % row_block != 0 makes ``jnp.pad`` a real O(N*D) copy)."""
    x_lead = state["x_lead"]
    n = x_lead.shape[0]
    B = min(row_block, n)
    nb = -(-n // B)
    pad = nb * B - n

    def rows(a, **kw):
        widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, widths, **kw).reshape(nb, B, *a.shape[1:])

    ids = state.get("row_ids")
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    xs = {
        "xl": rows(x_lead),
        "xt": rows(state["x_tail"]),
        "lsq": rows(state["lead_sq"]),
        "tsq": rows(state["tail_sq"]),
        "ids": rows(ids.astype(jnp.int32), constant_values=-1),
    }
    if "row_part" in state:     # partition-major layout for IVF probing
        xs["part"] = rows(state["row_part"].astype(jnp.int32), mode="edge")
    if "codes" in state:        # PQ codes for the opq rule
        xs["codes"] = rows(state["codes"].astype(jnp.int32))
    return xs


def _scan_blocks(cfg: DcoEngineConfig, state, xs, ql, qt, qe, pr, B, D):
    """Inner lax.scan over corpus row blocks for one query chunk."""
    from repro.kernels import ref
    from repro.kernels.ops import _on_tpu, dco_scan_op, pq_lookup_op

    c = ql.shape[0]
    k = cfg.k
    C = min(cfg.block_capacity, B)
    d1, Dt = ql.shape[1], qt.shape[1]
    # Mosaic requires (8, 128)-multiple tiles on real TPUs; interpret mode
    # (CPU) keeps tight tiles so tests don't pay for lane padding
    if cfg.use_kernel and _on_tpu():
        kb = dict(block_n=256, block_q=128, block_d=128)
        kb_pq = dict(block_n=128, block_q=8)
    else:
        kb = dict(block_n=min(256, _round8(B)), block_q=_round8(c),
                  block_d=min(128, _round8(d1)))
        kb_pq = dict(block_n=min(128, _round8(B)), block_q=_round8(c))
    scale = _final_scale(cfg, state, D)
    scales_arr = jnp.full((-(-d1 // kb["block_d"]),), scale, jnp.float32)
    qt_sq = (qt ** 2).sum(1)
    if cfg.kind == "ddcres":
        slack = 2.0 * cfg.m * jnp.sqrt(jnp.maximum(qe["var_d1"], 0.0))
        tail_min = state["tail_sq"].min()

    Cp = min(C + 1, B)      # +1 slot observes the best DROPPED estimate

    def step(carry, blk):
        best_d, best_i, tau, surv, passed = carry
        valid = blk["ids"] >= 0                               # (B,)
        rowhit = None
        tau_k = jnp.full((c,), jnp.inf) if cfg.kind == "fdscan" else tau
        if cfg.kind == "ddcres":
            # partial <= tau_k is implied by the Eq. 7 estimate test below
            tau_k = tau + slack - qe["qtail_sq"] - tail_min
        if pr is not None:
            # block-level probe gate: partition-major rows mean each block
            # spans [pmin, pmax]; unprobed blocks get tau=-1, which the
            # kernel's pl.when(any(alive)) turns into skipped matmuls
            pmin, pmax = blk["part"].min(), blk["part"].max()
            hit = ((pr >= pmin) & (pr <= pmax)).any(-1)       # (c,)
            tau_k = jnp.where(hit, tau_k, -1.0)
            rowhit = (blk["part"][None, :, None] == pr[:, None, :]).any(-1)

        passed_b = None
        if cfg.kind == "opq":
            if cfg.use_kernel:
                adist = pq_lookup_op(blk["codes"], qe["lut"], **kb_pq)
            else:
                adist = ref.pq_lookup_ref(blk["codes"], qe["lut"])
            est = adist.T / cfg.theta                         # (c, B)
            keep = (est <= tau[:, None]) & valid[None, :]
            partial = None
        elif cfg.use_kernel:
            nvalid = valid.sum().astype(jnp.int32)
            p, kp, cnt = dco_scan_op(blk["xl"], ql, tau_k, scales_arr,
                                     nvalid, **kb)
            partial, keep = p.T, kp.T.astype(bool)            # (c, B)
            est = partial * scale
            passed_b = cnt.sum(0)       # the kernel's per-block keep counts
        else:
            partial = jnp.maximum(
                blk["lsq"][None, :] - 2.0 * ql @ blk["xl"].T
                + (ql ** 2).sum(1)[:, None], 0.0)             # (c, B)
            est = partial * scale
            keep = (est <= tau_k[:, None]) & valid[None, :]
        if cfg.kind == "ddcres":
            # full-distance estimate (core.methods Eq. 7) refines the
            # conservative in-kernel partial screen and drives compaction
            est = (partial + blk["tsq"][None, :]
                   + qe["qtail_sq"][:, None] - slack[:, None])
            keep = keep & (est <= tau[:, None])
            passed_b = None
        if rowhit is not None:
            keep = keep & rowhit
            passed_b = None
        if passed_b is None:
            passed_b = keep.sum(-1).astype(jnp.int32)

        if cfg.kind == "fdscan":
            exact = partial + jnp.maximum(
                blk["tsq"][None, :] - 2.0 * qt @ blk["xt"].T
                + qt_sq[:, None], 0.0)
            ok = valid[None, :] if rowhit is None else (valid[None, :] & rowhit)
            exact = jnp.where(ok, exact, jnp.inf)
            new_d, new_i = _merge_topk(
                best_d, best_i, exact,
                jnp.broadcast_to(blk["ids"][None, :], (c, B)), k)
            n_done = ok.sum(-1).astype(jnp.int32)
            new_tau = jnp.full((c,), jnp.inf)
            return ((new_d, new_i, new_tau, surv + n_done, passed + n_done),
                    jnp.full((c,), jnp.inf))

        # ---- on-device compaction: top-C survivors by estimate ------------
        score = jnp.where(keep, est, jnp.inf)
        neg_s, cand = jax.lax.top_k(-score, Cp)               # (c, C [+1])
        # Column C (when present) is the best estimate among rows the budget
        # DROPPED: the exactness certificate — no true neighbor was lost iff
        # the final k-th distance stays below every dropped lower bound.  It
        # is read via a masked reduce and the extra column is disabled by
        # masking, NOT by slicing: XLA CPU only rewrites the top_k sort into
        # the O(n log k) TopK custom call when it feeds a single slice, and
        # a second column slice forced a full row sort (15x slower)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, Cp), 1)
        dropped = -jnp.max(jnp.where(col == C, neg_s, -jnp.inf), -1)
        alive = (neg_s > -jnp.inf) & (col < C)
        rows = jnp.arange(c)[:, None]
        c_tail = blk["xt"][cand]                              # (c, Cp, Dt)
        tail = jnp.maximum(((c_tail - qt[:, None, :]) ** 2).sum(-1), 0.0)
        if cfg.kind == "opq":
            c_lead = blk["xl"][cand]
            exact = jnp.maximum(((c_lead - ql[:, None, :]) ** 2).sum(-1), 0.0) + tail
        else:
            exact = partial[rows, cand] + tail
        exact = jnp.where(alive, exact, jnp.inf)
        new_d, new_i = _merge_topk(best_d, best_i, exact, blk["ids"][cand], k)
        new_tau = new_d[:, -1] * cfg.tau_slack                # tightens monotonely
        return ((new_d, new_i, new_tau,
                 surv + alive.sum(-1).astype(jnp.int32),
                 passed + passed_b), dropped)

    init = (jnp.full((c, k), jnp.inf, jnp.float32),
            jnp.full((c, k), -1, jnp.int32),
            jnp.full((c,), jnp.inf, jnp.float32),
            jnp.zeros((c,), jnp.int32), jnp.zeros((c,), jnp.int32))
    (d, i, _, surv, passed), dropped = jax.lax.scan(step, init, xs)
    return d, i, surv, passed, dropped.min(0)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _stream_topk_padded(state: dict, xs: dict, q_lead, q_tail, q_extra: dict,
                        probe, cfg: DcoEngineConfig):
    d1 = q_lead.shape[1]
    D = d1 + q_tail.shape[1]
    B = xs["xl"].shape[1]
    nq = q_lead.shape[0]
    c = min(cfg.query_chunk, nq)
    ql = q_lead.reshape(nq // c, c, -1)
    qt = q_tail.reshape(nq // c, c, -1)
    qe = {key: v.reshape(nq // c, c, *v.shape[1:]) for key, v in q_extra.items()}
    pr = None if probe is None else probe.reshape(nq // c, c, -1)

    def one_chunk(args):
        cql, cqt, cqe, cpr = args
        return _scan_blocks(cfg, state, xs, cql, cqt, cqe, cpr, B, D)

    d, i, surv, passed, dmin = jax.lax.map(one_chunk, (ql, qt, qe, pr))
    k = cfg.k
    return (d.reshape(nq, k), i.reshape(nq, k),
            surv.reshape(nq), passed.reshape(nq), dmin.reshape(nq))


def stream_topk(state: dict, q_lead, q_tail, cfg: DcoEngineConfig,
                q_extra: dict | None = None, probe=None, blocks=None):
    """Streaming top-k over the local corpus for a batch of rotated queries.

    q_lead (Q, d1), q_tail (Q, D - d1).  ``state`` is a
    ``jax_engine.build_device_state`` export, optionally extended with
    ``row_ids`` (original ids when rows were permuted), ``row_part`` +
    ``probe`` (Q, nprobe) for IVF probing, and ``codes`` for the opq rule.
    ``blocks`` is an optional pre-built :func:`build_stream_blocks` layout
    (built here when absent — repeat callers should cache it).  Ragged
    batches pad to a whole number of query chunks; N need not divide
    ``cfg.row_block``.  Returns (dists_sq (Q, k), ids (Q, k), survivors (Q,)
    rows tail-completed, passed (Q,) rows passing the screen,
    dropped_min_est (Q,) the smallest estimate among screen survivors the
    per-block completion budget dropped, +inf when nothing was dropped).
    ``dropped_min_est[q] > dists_sq[q, k-1]`` CERTIFIES exactness for
    lower-bound rules: every dropped row's lower bound exceeds the returned
    k-th distance, so no true neighbor was truncated.  A failed certificate
    means block_capacity should be raised (or row_block shrunk).
    """
    q_extra = dict(q_extra or {})
    if cfg.use_kernel is None:
        from repro.kernels.ops import _on_tpu
        cfg = dataclasses.replace(cfg, use_kernel=_on_tpu())
    if blocks is None:
        blocks = build_stream_blocks(state, cfg.row_block)
    nq = q_lead.shape[0]
    if nq == 0:
        raise ValueError("stream_topk needs at least one query")
    c = min(cfg.query_chunk, nq)
    pad = (-nq) % c
    if pad:
        q_lead = jnp.pad(q_lead, ((0, pad), (0, 0)))
        q_tail = jnp.pad(q_tail, ((0, pad), (0, 0)))
        q_extra = {key: jnp.pad(v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
                   for key, v in q_extra.items()}
        if probe is not None:
            probe = jnp.pad(probe, ((0, pad), (0, 0)))
    d, i, s, p, dm = _stream_topk_padded(state, blocks, q_lead, q_tail,
                                         q_extra, probe, cfg)
    return d[:nq], i[:nq], s[:nq], p[:nq], dm[:nq]
