"""Guardrail layer: drift sentinel, online recall audits, circuit breaker.

The paper's production verdict is that DCO screening is *unstable*: pruning
power collapses under query drift (OOD batches), and a screen that has gone
net-negative keeps burning cycles until a human notices.  PR 3's adaptive
policy reacts per block, but nothing detects *sustained* degradation and
durably demotes screening with a re-qualification path.  This module is
that layer (DESIGN.md §9):

**Drift sentinel** — at session build time we fit cheap reference
statistics of the indexed corpus: per-dim mean, the top-``lead_r``
principal directions (randomized subspace iteration on a row subsample —
a full D x D eigendecomposition is infeasible at ultra-high D), and the
reference fraction of centered energy that lands in that lead subspace.
Every incoming batch is scored by its *lead-energy deficit*: OOD batches in
the spectrum-shift regime (``vecdata.make_ood_queries`` — energy pushed
into the lowest-variance directions, where lower-bound screening prunes
nothing) lose almost all lead energy, so the deficit approaches 1 while
in-distribution batches sit near 0.  Corpora are typically stored under a
random rotation, so per-dim variances alone are ~isotropic and carry no
drift signal — the principal split is what makes the sentinel sensitive to
exactly the shift that breaks screening.  A norm-deviation term catches
scale drift the projection is blind to.  Scores fold into an EWMA.

**Online audit** — while the breaker is closed, a deterministic ~1/64
sample of served queries (fractional accumulator, seeded per batch index so
replays are reproducible) is shadow re-executed through the certified
full-scan path and compared against the screening answers: sampled recall
and the screened-vs-certified wall-clock ratio feed EWMAs.  Audits never
touch the served results — closed-state answers are bit-identical with or
without guardrails.

**Circuit breaker** — per (method, backend) state machine::

    closed --(sustained drift AND evidence)--> open
    open   --(drift EWMA back under threshold, dwell served)--> half_open
    half_open --(canary screen fails or drift resurges)--> open
    half_open --(promote_after clean canaries, dwell served)--> closed

While open (and half-open), every batch is served by the certified
full-scan body the adaptive machinery already jits
(``PolicyConfig(force_fallback=True)`` -> ``step_full``): recall is exact
by construction, so a tripped breaker bounds the damage at fdscan cost.
Half-open batches are still served certified; the *canary* shadow-screens a
sampled query and compares it against the certified answers, so a failed
probe costs nothing served.  ``min_dwell`` gates every serving-mode flip
(closed->open, half_open->closed) and the open->half_open probe decision,
bounding flaps under alternating id/ood bursts to at most one transition
per dwell window; a failed canary re-opens immediately (both states serve
the same certified path, so that flip changes no served result).

Evidence for the trip is any of: audited recall EWMA under
``audit_recall_floor``, this batch's uncertified-certificate fraction over
``uncertified_ceiling`` (severe OOD overflows the per-block completion
budget immediately — the fastest honest signal), or the audited cost ratio
over ``cost_ceiling`` (screening slower than the certified scan).  Drift
alone never trips (the sentinel could be wrong); evidence alone never
trips (a one-off capacity spill is the adaptive policy's job).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.engine import (EXTRA_AUDIT_RECALL, EXTRA_BREAKER_STATE,
                               EXTRA_DRIFT_SCORE, EXTRA_UNCERTIFIED_QUERIES)
from repro.testing import faults

#: Breaker states (``Guardrail.state`` / the ``breaker_state`` stat).
BREAKER_STATES = ("closed", "open", "half_open")


class BreakerCore:
    """The bare closed -> open -> half_open state machine: current state,
    dwell bookkeeping, and a bounded transition log.

    Two owners share it: the drift guardrail below (demotes DCO screening,
    DESIGN.md §9) and the replicated serving tier's per-replica ejection
    breaker (``serving.replica``, DESIGN.md §10).  The core is mechanism
    only — *when* to flip (drift + evidence, consecutive failures, probe
    outcomes) stays with the owner; the core records flips, resets dwell,
    and rejects unknown state names.
    """

    def __init__(self):
        self.state = "closed"
        self.dwell = 0                      # steps spent in the current state
        self.transitions: deque = deque(maxlen=256)

    def tick(self) -> None:
        """One observation in the current state (dwell grows by one)."""
        self.dwell += 1

    def transition(self, to: str, reason: str, *, at: int = 0) -> None:
        """Flip to ``to`` (validated), logging ``{at, from, to, reason}``
        and resetting dwell."""
        if to not in BREAKER_STATES:
            raise ValueError(
                f"breaker state must be one of {BREAKER_STATES}, got {to!r}")
        self.transitions.append(
            {"batch": int(at), "from": self.state, "to": to,
             "reason": reason})
        self.state = to
        self.dwell = 0


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    """Static guardrail knobs (hashable: rides inside the frozen
    ``SchedulePolicy``).

    ``drift_threshold``     EWMA drift score above which a batch counts as
                            drifted (lead-energy deficit is ~0 in
                            distribution, ~1 under a full spectrum shift).
    ``drift_alpha``         EWMA weight of the newest batch's raw score.
    ``trip_after``          consecutive drifted batches (with evidence)
                            before closed -> open.
    ``min_dwell``           batches a state must hold before a serving-mode
                            transition (closed->open, half_open->closed) or
                            an open->half_open probe; bounds flapping.
    ``promote_after``       consecutive clean canaries before half_open ->
                            closed.
    ``audit_rate``          expected fraction of served queries shadow
                            re-executed through the certified path while
                            closed (fractional accumulator: exact in
                            expectation, deterministic given the seed).
    ``audit_batch``         queries per shadow audit call.  The accumulator
                            waits until a full group is owed, then audits
                            them together from the current batch: the
                            shadow search pads to the engine's query chunk
                            anyway, so G queries cost the same wall as 1 —
                            larger groups mean the same audited fraction at
                            ~1/G the shadow dispatches (that amortization
                            is what keeps audit overhead in the low single
                            digits; see the bench_robustness control cell).
                            Also the per-batch cap on audit work.
    ``canary_queries``      queries shadow-screened per half-open batch.
    ``audit_recall_floor``  audited/canary recall below this is evidence of
                            a failing screen (estimator rules with a
                            naturally lossy screen may need it lowered).
    ``uncertified_ceiling`` batch certificate-failure fraction above this
                            is evidence (capacity overflow under OOD).
    ``cost_ceiling``        screened-vs-certified per-query wall ratio
                            above this is evidence (screening net-negative).
    ``lead_r``              principal directions in the sentinel's lead
                            split (clamped to D // 4).
    ``seed``                sentinel subsampling + audit/canary sampling
                            seed (replays are reproducible).
    """

    drift_threshold: float = 0.35
    drift_alpha: float = 0.5
    trip_after: int = 2
    min_dwell: int = 4
    promote_after: int = 2
    audit_rate: float = 1.0 / 64.0
    audit_batch: int = 16
    canary_queries: int = 1
    audit_recall_floor: float = 0.999
    uncertified_ceiling: float = 0.25
    cost_ceiling: float = 1.0
    lead_r: int = 32
    seed: int = 0


class DriftSentinel:
    """Reference statistics of the fitted corpus + batch drift scoring.

    Fit once per session from the method's stored corpus; ``score`` is
    O(nq * D * r) per batch — noise next to one corpus block's matmul.
    """

    def __init__(self, mean, lead, ref_lead_frac, ref_norm):
        self.mean = mean                    # (D,) corpus mean
        self.lead = lead                    # (D, r) orthonormal lead basis
        self.ref_lead_frac = ref_lead_frac  # corpus energy fraction in lead
        self.ref_norm = ref_norm            # mean centered row norm

    @classmethod
    def fit(cls, X, *, r: int = 32, seed: int = 0,
            sample: int = 4096) -> "DriftSentinel":
        """Fit from corpus rows: subsample, then randomized subspace
        iteration for the top-``r`` principal directions (two power steps —
        plenty for a split this coarse, and it never materializes D x D)."""
        X = np.asarray(X, np.float32)
        n, D = X.shape
        rng = np.random.default_rng(seed)
        sub = X if n <= sample else X[rng.choice(n, sample, replace=False)]
        mu = sub.mean(0)
        Xc = (sub - mu).astype(np.float64)
        r = max(1, min(int(r), max(1, D // 4), Xc.shape[0] - 1))
        Y = Xc.T @ (Xc @ rng.standard_normal((D, min(D, r + 8))))
        for _ in range(2):
            Q, _ = np.linalg.qr(Y)
            Y = Xc.T @ (Xc @ Q)
        Q, _ = np.linalg.qr(Y)
        B = Xc @ Q
        _, _, Vt = np.linalg.svd(B, full_matrices=False)
        lead = (Q @ Vt[:r].T).astype(np.float32)          # (D, r)
        tot = np.maximum((Xc ** 2).sum(1), 1e-12)
        frac = ((Xc @ lead) ** 2).sum(1) / tot
        return cls(mu.astype(np.float32), lead,
                   float(frac.mean()), float(np.sqrt(tot).mean()))

    def score(self, Q) -> float:
        """Raw drift score of one batch in [0, 1]: the batch's mean
        lead-energy deficit relative to the corpus reference, maxed with a
        clipped norm-deviation term (scale drift)."""
        Qc = np.asarray(Q, np.float32) - self.mean
        tot = np.maximum((Qc ** 2).sum(1), 1e-12)
        frac = float((((Qc @ self.lead) ** 2).sum(1) / tot).mean())
        deficit = max(0.0, (self.ref_lead_frac - frac)
                      / max(self.ref_lead_frac, 1e-9))
        norm_dev = abs(float(np.sqrt(tot).mean()) / max(self.ref_norm, 1e-9)
                       - 1.0)
        return float(min(1.0, max(deficit, min(norm_dev, 1.0))))


def _sample_recall(test_ids, ref_ids, k: int) -> float:
    """Top-k overlap of the screening answers vs the certified answers,
    averaged over the sampled queries (1.0 = identical neighbor sets)."""
    hits = 0
    for t, ref in zip(np.asarray(test_ids), np.asarray(ref_ids)):
        hits += len(set(map(int, t[:k])) & set(map(int, ref[:k])))
    return hits / float(max(k * len(np.asarray(ref_ids)), 1))


class Guardrail:
    """Mutable per-(method, backend) breaker runtime; owns the sentinel,
    the audit/canary sampling state, and the transition log.

    The backend routes every non-deadline batch through :meth:`run`, which
    dispatches to the screening or certified callable by breaker state and
    stamps ``drift_score`` / ``audit_recall`` / ``breaker_state`` into the
    batch stats.  Results in the closed state are bit-identical to an
    unguarded session (observation and audits never touch the served
    arrays).
    """

    def __init__(self, cfg: GuardrailConfig, method, backend: str):
        self.cfg = cfg
        self.method_name = method.name
        self.backend_name = backend
        self.sentinel = DriftSentinel.fit(
            method.state["X"], r=cfg.lead_r, seed=cfg.seed)
        self._core = BreakerCore()  # state + dwell + transition log
        self.batches = 0            # batches observed over the lifetime
        self.drift_raw = 0.0
        self.drift_ewma = 0.0
        self.audit_recall = 1.0     # EWMA of audited/canary sample recall
        self.cost_ratio = 0.0       # EWMA screened/certified wall per query
        self.drift_streak = 0
        self.promote_streak = 0
        self.audits = 0             # audited batches (closed state)
        self.audited_queries = 0
        self.canaries = 0           # canary probes (half-open state)
        self.demoted_batches = 0    # batches served by the certified path
        self._audit_acc = 0.0       # fractional audit accumulator

    # -- state machine (delegated to BreakerCore) ----------------------------
    @property
    def state(self) -> str:
        return self._core.state

    @property
    def dwell(self) -> int:
        return self._core.dwell

    @property
    def transitions(self) -> deque:
        return self._core.transitions

    def _transition(self, to: str, reason: str) -> None:
        self._core.transition(to, reason, at=self.batches)
        self.drift_streak = 0
        self.promote_streak = 0

    def force_state(self, state: str) -> None:
        """Operator/test override: jump the breaker to ``state`` (logged)."""
        self._transition(state, "forced")

    # -- sampling ------------------------------------------------------------
    def _take_audit(self, nq: int) -> int:
        """Fractional-accumulator sampling: audited queries are
        ``audit_rate`` of served queries in the long run, deterministic,
        and flushed in groups of ``audit_batch`` (one shadow dispatch per
        group; audit work per batch is capped at one group, so the
        effective rate saturates at ``audit_batch / nq`` for huge
        batches)."""
        self._audit_acc += nq * self.cfg.audit_rate
        g = max(1, self.cfg.audit_batch)
        if self._audit_acc < g:
            return 0
        n = min(g, nq)
        self._audit_acc -= n
        return n

    def _sample(self, nq: int, n: int) -> np.ndarray:
        """Deterministic query pick for this batch index (seeded, so a
        replay of the same stream audits the same queries)."""
        rng = np.random.default_rng([self.cfg.seed, self.batches])
        return np.sort(rng.choice(nq, size=min(n, nq), replace=False))

    def _fold_audit(self, recall: float, cost: float | None) -> None:
        a = self.cfg.drift_alpha
        self.audit_recall = (recall if self.audits + self.canaries == 0
                             else a * recall + (1 - a) * self.audit_recall)
        if cost is not None:
            self.cost_ratio = (cost if self.audits == 0
                               else a * cost + (1 - a) * self.cost_ratio)

    # -- the guarded batch ---------------------------------------------------
    def run(self, Q, k: int, *, screen, certified, plan=None):
        """Serve one batch under the breaker.

        ``screen(Q)`` / ``certified(Q)`` are backend callables returning
        ``(dists, ids, stats)`` — the configured screening path and the
        certified full-scan path.  ``plan`` is an optional
        ``testing.FaultPlan`` whose drift/audit overrides make state-machine
        edges deterministically testable."""
        cfg = self.cfg
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        nq = Q.shape[0]
        raw = faults.drift_override(plan, self.sentinel.score(Q))
        a = cfg.drift_alpha
        self.drift_raw = raw
        self.drift_ewma = (raw if self.batches == 0
                           else a * raw + (1 - a) * self.drift_ewma)
        drifted = self.drift_ewma > cfg.drift_threshold
        self.drift_streak = self.drift_streak + 1 if drifted else 0
        served_state = self.state

        if self.state == "closed":
            t0 = time.perf_counter()
            d, i, stats = screen(Q)
            wall = time.perf_counter() - t0
            unc = float(stats.extra.get(EXTRA_UNCERTIFIED_QUERIES, 0.0))
            n_aud = self._take_audit(nq)
            if n_aud:
                idx = self._sample(nq, n_aud)
                t0 = time.perf_counter()
                _, ref_ids, _ = certified(Q[idx])
                ref_wall = time.perf_counter() - t0
                rec = faults.audit_override(
                    plan, _sample_recall(i[idx], ref_ids, k))
                cost = ((wall / max(nq, 1))
                        / max(ref_wall / len(idx), 1e-9))
                self._fold_audit(rec, cost)
                self.audits += 1
                self.audited_queries += len(idx)
            evidence = (self.audit_recall < cfg.audit_recall_floor
                        or unc > cfg.uncertified_ceiling
                        or self.cost_ratio > cfg.cost_ceiling)
            self.batches += 1
            self._core.tick()
            if (drifted and self.drift_streak >= cfg.trip_after
                    and evidence and self.dwell >= cfg.min_dwell):
                self._transition(
                    "open",
                    f"drift ewma {self.drift_ewma:.3f} x{cfg.trip_after}+ "
                    f"with evidence (audit_recall {self.audit_recall:.3f}, "
                    f"uncertified {unc:.3f}, cost {self.cost_ratio:.2f})")
        else:
            d, i, stats = certified(Q)
            self.demoted_batches += 1
            if self.state == "half_open":
                idx = self._sample(nq, max(1, cfg.canary_queries))
                _, can_ids, _ = screen(Q[idx])
                rec = faults.audit_override(
                    plan, _sample_recall(can_ids, i[idx], k))
                self._fold_audit(rec, None)
                self.canaries += 1
                ok = rec >= cfg.audit_recall_floor and not drifted
                self.promote_streak = self.promote_streak + 1 if ok else 0
                self.batches += 1
                self._core.tick()
                if not ok:
                    # re-open immediately: half-open batches are already
                    # served certified, so this flip changes nothing served
                    self._transition(
                        "open", f"canary failed (recall {rec:.3f}, drift "
                        f"ewma {self.drift_ewma:.3f})")
                elif (self.promote_streak >= cfg.promote_after
                        and self.dwell >= cfg.min_dwell):
                    self._transition(
                        "closed", f"{self.promote_streak} clean canaries "
                        f"(recall {self.audit_recall:.3f})")
            else:                           # open
                self.batches += 1
                self._core.tick()
                if not drifted and self.dwell >= cfg.min_dwell:
                    self._transition(
                        "half_open",
                        f"drift ewma {self.drift_ewma:.3f} recovered")
        stats.extra[EXTRA_DRIFT_SCORE] = float(self.drift_ewma)
        stats.extra[EXTRA_AUDIT_RECALL] = float(self.audit_recall)
        stats.extra[EXTRA_BREAKER_STATE] = served_state
        return d, i, stats

    # -- observability -------------------------------------------------------
    def report(self) -> dict:
        """Snapshot for ``session.guardrails()`` / ``SearchService.health()``:
        breaker state, sentinel EWMAs, audit counters, and the transition
        log (most recent last)."""
        return {
            "method": self.method_name,
            "backend": self.backend_name,
            "state": self.state,
            "batches": self.batches,
            "dwell": self.dwell,
            "drift_score": float(self.drift_ewma),
            "drift_raw": float(self.drift_raw),
            "audit_recall": float(self.audit_recall),
            "cost_ratio": float(self.cost_ratio),
            "audits": self.audits,
            "audited_queries": self.audited_queries,
            "canaries": self.canaries,
            "demoted_batches": self.demoted_batches,
            "transitions": list(self.transitions),
        }
