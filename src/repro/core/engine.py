"""Staged top-k scan engine (host/numpy path).

This is the batched reformulation of Alg. 1/2/3's inner loop: for each block
of candidates, run the method's screening stages with *real compaction*
(survivors only move to the next stage), then complete exact distances in
original coordinates and merge into the running top-k.  The running k-th best
distance is the DCO threshold ``tau`` — exactly the paper's setting where the
vast majority of DCOs return False.

Stats tracked per search (paper's evaluation metrics):
  dims_scanned / dims_total  -> dimension pruning ratio (Fig. 6)
  n_dco, n_exact             -> fraction of DCOs returning True
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# --- canonical ScanStats.extra keys -----------------------------------------
# Both backends report batch telemetry under these names and ONLY these names
# (api.types re-exports and documents them as STAT_EXTRA_KEYS; the fix for
# the host/jax key drift lives here — add new keys here, never inline).
EXTRA_SURVIVORS_MEAN = "survivors_mean"          # rows exactly completed / query
EXTRA_SCREEN_PASS_MEAN = "screen_pass_mean"      # rows passing the screen / query
EXTRA_UNCERTIFIED_QUERIES = "uncertified_queries"  # frac with failed certificate
EXTRA_FALLBACK_BLOCKS = "fallback_blocks"        # adaptive: fdscan blocks / query
EXTRA_EST_SAVED_FLOPS = "est_saved_flops"        # adaptive: saved vs fdscan, batch
EXTRA_RULE_TIMELINE = "rule_timeline"            # adaptive: fallback frac / block
EXTRA_UNCERTIFIED_MASK = "uncertified_mask"      # per-query certificate failures
EXTRA_COVERAGE = "coverage"                      # per-query scanned fraction
                                                 # (anytime search; 1.0 = full)
EXTRA_DIMS_READ_MEAN = "dims_read_mean"          # dims touched per candidate
                                                 # (screen + completed tails)
EXTRA_DRIFT_SCORE = "drift_score"                # guardrails: EWMA drift score
EXTRA_AUDIT_RECALL = "audit_recall"              # guardrails: audited recall EWMA
EXTRA_BREAKER_STATE = "breaker_state"            # guardrails: breaker state that
                                                 # served the batch
EXTRA_DEGRADED = "degraded"                      # replica tier: 1.0 when the
                                                 # batch lost >= 1 shard
EXTRA_REPLICA = "replica"                        # replica tier: serving replica
                                                 # index (-1 = sharded fan-out)
EXTRA_HEDGED = "hedged"                          # replica tier: 1.0 when a
                                                 # hedge served/raced the batch


def make_schedule(D: int, delta0: int = 32, delta_d: int = 64, max_stages: int = 4):
    """Stage dims per the paper's (Delta_0, Delta_d) parameterization, capped
    to a handful of stages (block-level screening; DESIGN.md §3)."""
    dims, d = [], delta0
    while d < D and len(dims) < max_stages:
        dims.append(d)
        d += delta_d
        delta_d *= 2          # geometric growth keeps stage count bounded
    return dims


@dataclass
class ScanStats:
    dims_scanned: float = 0.0
    dims_total: float = 0.0
    n_dco: int = 0
    n_true: int = 0
    extra: dict = field(default_factory=dict)

    @property
    def pruning_ratio(self) -> float:
        return 1.0 - self.dims_scanned / max(self.dims_total, 1e-9)


@dataclass
class QueryBatch:
    """One prepped batch of queries flowing through the scan/index layers.

    Bundles the method's online pre-processing output (``ctx``, which holds
    the raw queries under ``"Q"`` plus any rotated views), the stage schedule,
    and the per-batch ``ScanStats`` — replacing the loose
    ``(ctx, qi, q, schedule, stats)`` tuple that every search signature used
    to thread by hand.
    """

    ctx: dict
    schedule: list
    stats: ScanStats

    @classmethod
    def create(cls, method, Q, schedule=None, stats: ScanStats | None = None):
        """Prep ``Q`` with ``method`` and attach a schedule (defaults to the
        paper's (Delta_0, Delta_d) schedule for the method's D)."""
        ctx = method.prep_queries(Q)
        if schedule is None:
            schedule = make_schedule(method.state["D"])
        return cls(ctx, list(schedule), stats if stats is not None else ScanStats())

    @property
    def Q(self):
        return self.ctx["Q"]

    def __len__(self) -> int:
        return int(self.ctx["Q"].shape[0])


def topk_merge(best_d, best_i, new_d, new_i, k):
    d = np.concatenate([best_d, new_d])
    i = np.concatenate([best_i, new_i])
    order = np.argpartition(d, min(k - 1, len(d) - 1))[:k]
    order = order[np.argsort(d[order])]
    return d[order], i[order]


def scan_topk(method, batch: QueryBatch, qi: int, cand_ids, k, *,
              block: int = 1024, init_d=None, init_i=None, policy=None,
              deadline_ts=None):
    """DCO-accelerated exact-completion top-k over ``cand_ids`` for query
    ``qi`` of ``batch``.  Stats accumulate into ``batch.stats``.

    ``policy`` (a ``core.policy.PolicyConfig`` with ``adaptive=True``)
    enables the adaptive fallback of DESIGN.md §5: when the running survivor
    fraction says screening is net-negative, later blocks skip the stage
    loop and complete every candidate exactly (an fdscan block).  Fallback
    only *adds* scanned dims, so results are unchanged — the host scan
    completes every survivor exhaustively either way.

    ``deadline_ts`` (absolute ``time.monotonic()`` timestamp) arms anytime
    mode (DESIGN.md §7): the wall clock is checked before each candidate
    block and on expiry the running top-k is returned as-is.  The fraction
    of candidate blocks actually scanned is appended to the private
    ``stats.extra["_coverage"]`` list (one entry per scan call, in call
    order); the backend folds it into the public ``EXTRA_COVERAGE`` array
    and flags partial queries via ``EXTRA_UNCERTIFIED_MASK``.
    """
    import time as _time

    from repro.testing import faults

    D = method.state["D"]
    ctx, stats = batch.ctx, batch.stats
    stages = method.stage_dims(batch.schedule)
    hp = None
    if policy is not None and getattr(policy, "adaptive", False) and stages:
        from repro.core.policy import HostPolicy
        hp = HostPolicy(policy, D)
    best_d = init_d if init_d is not None else np.full(k, np.inf, np.float32)
    best_i = init_i if init_i is not None else np.full(k, -1, np.int64)
    cand_ids = np.asarray(cand_ids, np.int64)
    fp = faults.active() if deadline_ts is not None else None
    blocks_done, n_blocks = 0, max(1, -(-len(cand_ids) // block))
    for s in range(0, len(cand_ids), block):
        if deadline_ts is not None:
            if _time.monotonic() > deadline_ts:
                break
            faults.sleep_block(fp)
        blocks_done += 1
        ids = cand_ids[s:s + block]
        tau_sq = float(best_d[-1])
        alive = ids
        fallback = hp is not None and hp.mode
        charged_blk = 0.0
        if stats is not None:
            stats.n_dco += len(ids)
            stats.dims_total += len(ids) * D
        if np.isfinite(tau_sq):
            if fallback:
                # shadow screen at the first stage only: keeps the survivor
                # signal alive for recovery, prunes nothing (alive stays ids)
                d0 = max(stages[0], 1)
                keep, charged = method.screen(ids, ctx, qi, d0, tau_sq)
                charged_blk = len(ids) * charged
                if stats is not None:
                    stats.dims_scanned += charged_blk
                hp.observe(len(ids), int(keep.sum()), charged)
            else:
                # methods exposing partial_range (pure-partial lower bounds:
                # PDScanning/+) screen incrementally: each stage reads only
                # the strided dim group [prev_d, d) and adds it to a carried
                # partial — the host mirror of the device PDX layout
                # (DESIGN.md §8).  Same keep decisions (the accumulated
                # partial IS the stage partial), fewer dims charged.
                pr_fn = getattr(method, "partial_range", None)
                acc, prev_d = None, 0
                for d in stages:
                    if len(alive) == 0:
                        break
                    d_eff = max(d, 1)
                    if pr_fn is not None:
                        if d_eff <= prev_d:
                            continue
                        part = pr_fn(alive, ctx, qi, prev_d, d_eff)
                        acc = part if acc is None else acc + part
                        keep, charged = acc <= tau_sq, float(d_eff - prev_d)
                        prev_d = d_eff
                    else:
                        keep, charged = method.screen(alive, ctx, qi, d_eff,
                                                      tau_sq)
                    charged_blk += len(alive) * charged
                    if stats is not None:
                        stats.dims_scanned += len(alive) * charged
                    alive = alive[keep]
                    if acc is not None:
                        acc = acc[keep]
                if hp is not None:
                    hp.observe(len(ids), len(alive), charged_blk / len(ids))
        if hp is not None:
            hp.block_served(fallback, len(ids), len(alive), charged_blk)
        if len(alive) == 0:
            continue
        ex = method.exact_sq(alive, ctx, qi)
        if stats is not None:
            stats.dims_scanned += len(alive) * D
            stats.n_true += int((ex <= tau_sq).sum()) if np.isfinite(tau_sq) else len(alive)
            # host completion == screen pass (no completion budget); the
            # backend converts these totals to the per-query means of
            # EXTRA_SURVIVORS_MEAN / EXTRA_SCREEN_PASS_MEAN
            stats.extra["_completed_total"] = (
                stats.extra.get("_completed_total", 0) + len(alive))
        best_d, best_i = topk_merge(best_d, best_i, ex.astype(np.float32), alive, k)
    if hp is not None:
        hp.flush(stats)
    if deadline_ts is not None and stats is not None:
        cov = 1.0 if len(cand_ids) == 0 else blocks_done / n_blocks
        stats.extra.setdefault("_coverage", []).append(cov)
    return best_d, best_i
