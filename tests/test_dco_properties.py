"""Property-based tests (hypothesis) for core DCO invariants."""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import transforms as T
from repro.core.engine import QueryBatch, make_schedule, scan_topk, topk_merge
from repro.core.methods import make_method

dims = st.integers(min_value=4, max_value=96)
ns = st.integers(min_value=20, max_value=200)


@settings(max_examples=25, deadline=None)
@given(n=ns, d=dims, seed=st.integers(0, 2**16))
def test_pca_rotation_preserves_distances(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    pca = T.fit_pca(X)
    Xr = T.pca_rotate(pca, X)
    if pca["rank"] == d:                       # full rotation
        a, b = Xr[0] - Xr[1], X[0] - X[1]
        np.testing.assert_allclose((a * a).sum(), (b * b).sum(), rtol=1e-3)
    # W columns orthonormal always
    WtW = pca["W"].T @ pca["W"]
    np.testing.assert_allclose(WtW, np.eye(pca["rank"]), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(n=ns, d=dims, dpart=st.integers(1, 16), seed=st.integers(0, 2**16))
def test_partial_distance_is_lower_bound(n, d, dpart, seed):
    """Partial ssd over any orthonormal prefix lower-bounds the full ssd —
    the exactness guarantee of PDScanning/PDScanning+."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    q = rng.standard_normal((1, d)).astype(np.float32)
    dpart = min(dpart, d)
    for name in ("PDScanning", "PDScanning+", "ADSampling"):
        m = make_method(name).fit(X)
        ctx = m.prep_queries(q)
        full = m.exact_sq(np.arange(n), ctx, 0)
        Xr = m.state.get("Xrot", X)
        Qr = ctx.get("Qrot", ctx["Q"])
        r = min(dpart, Xr.shape[1])
        partial = ((Xr[:, :r] - Qr[0, :r]) ** 2).sum(1)
        assert (partial <= full * (1 + 1e-3) + 1e-4).all(), name


@settings(max_examples=20, deadline=None)
@given(n=st.integers(30, 150), d=dims, k=st.integers(1, 10),
       seed=st.integers(0, 2**16))
def test_exact_scan_topk_equals_bruteforce(n, d, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    q = rng.standard_normal((1, d)).astype(np.float32)
    k = min(k, n)
    m = make_method("PDScanning+").fit(X)
    batch = QueryBatch.create(m, q, make_schedule(d))
    bd, bi = scan_topk(m, batch, 0, np.arange(n), k, block=32)
    brute = ((X - q[0]) ** 2).sum(1)
    expect = np.sort(brute)[:k]
    np.testing.assert_allclose(np.asarray(bd), expect, rtol=1e-3, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 8), n1=st.integers(0, 10), n2=st.integers(0, 10),
       seed=st.integers(0, 2**16))
def test_topk_merge_invariants(k, n1, n2, seed):
    rng = np.random.default_rng(seed)
    best_d = np.full(k, np.inf, np.float32)
    best_i = np.full(k, -1, np.int64)
    new_d = rng.random(n2).astype(np.float32)
    new_i = rng.integers(0, 1000, n2)
    md, mi = topk_merge(best_d, best_i, new_d, new_i, k)
    fin = np.isfinite(md)
    assert len(md) == k and (np.diff(md[fin]) >= 0).all()
    allv = np.concatenate([best_d, new_d])
    np.testing.assert_allclose(md, np.sort(allv)[:k])


@settings(max_examples=15, deadline=None)
@given(n=st.integers(150, 400), d=st.integers(8, 64), seed=st.integers(0, 2**16))
def test_pq_adist_nonnegative_and_close(n, d, seed):
    """PQ approximate distances are nonnegative and correlate with the truth.
    (On isotropic Gaussian data the correlation floor is weak by nature —
    the paper's DDCopq targets CLUSTERED embeddings; bench_query covers that.)"""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    pq = T.fit_pq(X, n_sub=4, n_codes=32, iters=4)
    q = rng.standard_normal(d).astype(np.float32)
    lut = T.pq_query_lut(pq, q)
    adist = T.pq_adist(pq, lut, pq["codes"])
    true = ((X - q) ** 2).sum(1)
    assert (adist >= 0).all()
    # quantized distance correlates with true distance
    corr = np.corrcoef(adist, true)[0, 1]
    assert corr > 0.3
