"""Anytime deadlines, overload shedding, and fault injection (DESIGN.md §7).

Three contracts:

1. **Anytime search is a pure generalization**: with a generous deadline the
   result is bit-identical to the non-deadline path on BOTH backends (the
   grouped scan replays the exact per-block step sequence).  With a tight
   deadline it returns the running top-k over a *prefix* of the corpus
   blocks — coverage < 1, certificate withdrawn, and (at
   block_capacity == row_block, where the stream scan is exact) the ids are
   exactly the brute-force top-k of the scanned prefix.

2. **Overload resolves every ticket**: bounded admission sheds, queued
   budget expiry times out, device faults fail only their batch — and the
   counters account for every submitted request.

3. **Fault injection is deterministic and scoped** (testing.faults).
"""
import numpy as np
import pytest

from repro.api import SchedulePolicy, open_index
from repro.core.engine import (EXTRA_COVERAGE, EXTRA_UNCERTIFIED_MASK,
                               EXTRA_UNCERTIFIED_QUERIES)
from repro.testing import FaultError, FaultPlan, faults


def _data(n=2048, d=24, nq=8, seed=7):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(nq, d)).astype(np.float32))


def _pol(**kw):
    kw.setdefault("d1", 24)
    kw.setdefault("query_chunk", 4)
    kw.setdefault("row_block", 256)
    kw.setdefault("block_capacity", 256)
    kw.setdefault("anytime_block_group", 2)
    return SchedulePolicy(**kw)


# ------------------------------------------------- deadline = ∞ identity ----
@pytest.mark.parametrize("backend", ["host", "jax"])
@pytest.mark.parametrize("deadline", [1e6, np.inf])
def test_generous_deadline_is_bit_identical(backend, deadline):
    X, Q = _data()
    sess = open_index(X, backend=backend, schedule=_pol())
    r0 = sess.search(Q, 10)
    r1 = sess.search(Q, 10, deadline_s=float(deadline))
    assert np.array_equal(r0.ids, r1.ids)
    assert np.array_equal(r0.dists, r1.dists)
    cov = r1.stats.extra[EXTRA_COVERAGE]
    assert cov.shape == (Q.shape[0],) and (cov == 1.0).all()
    assert not r1.stats.extra[EXTRA_UNCERTIFIED_MASK].any()


@pytest.mark.parametrize("backend", ["host", "jax"])
def test_generous_deadline_is_bit_identical_ivf(backend):
    X, Q = _data()
    sess = open_index(X, index="ivf", backend=backend, schedule=_pol())
    r0 = sess.search(Q, 10, nprobe=8)
    r1 = sess.search(Q, 10, nprobe=8, deadline_s=1e6)
    assert np.array_equal(r0.ids, r1.ids)
    assert np.array_equal(r0.dists, r1.dists)


# ----------------------------------------------------- partial coverage -----
def test_jax_tight_deadline_partial_prefix():
    """Expired deadline → coverage < 1, certificate withdrawn, and the ids
    are EXACTLY the brute-force top-k of the scanned block prefix (the
    running top-k is exact at block_capacity == row_block)."""
    X, Q = _data()
    pol = _pol(anytime_block_group=1)
    sess = open_index(X, backend="jax", schedule=pol)
    sess.search(Q, 10)                        # warm the jit cache
    with faults.inject(slow_block_s=0.05):
        res = sess.search(Q, 10, deadline_s=0.01)
    cov = res.stats.extra[EXTRA_COVERAGE]
    assert (cov < 1.0).all()                  # jax: batch advances together
    assert (cov > 0.0).all()                  # ... but ≥ 1 group always runs
    assert res.stats.extra[EXTRA_UNCERTIFIED_MASK].all()
    assert res.stats.extra[EXTRA_UNCERTIFIED_QUERIES] == 1.0
    nb = -(-X.shape[0] // pol.row_block)
    done = round(float(cov[0]) * nb)
    prefix = X[: done * pol.row_block]
    d2 = ((Q[:, None] - prefix[None]) ** 2).sum(-1)
    oracle = np.argsort(d2, 1)[:, :10]
    for i in range(Q.shape[0]):
        assert set(res.ids[i].tolist()) == set(oracle[i].tolist())


def test_pdx_slow_block_deadline_partial_prefix():
    """PDX layout under the slow-block fault: an expiring deadline must
    still return the exact brute-force top-k of the scanned block prefix
    (block_capacity == row_block keeps both the completion budget and the
    grouped R-cut from dropping anything), with the certificate withdrawn
    for the unscanned suffix."""
    X, Q = _data()
    pol = _pol(d1=16, dim_groups=4, use_kernel=False, anytime_block_group=1)
    sess = open_index(X, backend="jax", schedule=pol)
    sess.search(Q, 10)                        # warm the jit cache
    with faults.inject(slow_block_s=0.05):
        res = sess.search(Q, 10, deadline_s=0.01)
    cov = res.stats.extra[EXTRA_COVERAGE]
    assert (cov < 1.0).all() and (cov > 0.0).all()
    assert res.stats.extra[EXTRA_UNCERTIFIED_MASK].all()
    nb = -(-X.shape[0] // pol.row_block)
    done = round(float(cov[0]) * nb)
    prefix = X[: done * pol.row_block]
    d2 = ((Q[:, None] - prefix[None]) ** 2).sum(-1)
    oracle = np.argsort(d2, 1)[:, :10]
    for i in range(Q.shape[0]):
        assert set(res.ids[i].tolist()) == set(oracle[i].tolist())


def test_host_tight_deadline_is_per_query():
    """The host scan serves queries sequentially, so an expiring budget
    yields full coverage for early queries and zero for the starved tail —
    and only the starved ones lose their certificate."""
    X, Q = _data()
    sess = open_index(X, backend="host", schedule=_pol())
    with faults.inject(slow_block_s=0.03):
        res = sess.search(Q, 10, deadline_s=0.04)
    cov = res.stats.extra[EXTRA_COVERAGE]
    mask = res.stats.extra[EXTRA_UNCERTIFIED_MASK]
    assert cov[0] > 0.0                       # first query got real budget
    assert (cov < 1.0).any()
    assert (mask == (cov < 1.0)).all()
    full = cov == 1.0
    if full.any():                            # served-in-time queries exact
        d2 = ((Q[full][:, None] - X[None]) ** 2).sum(-1)
        oracle = np.sort(d2, 1)[:, :10]
        assert np.allclose(res.dists[full], oracle, rtol=1e-4, atol=1e-4)


def test_deadline_rejected_where_meaningless():
    X, Q = _data(n=512)
    hnsw = open_index(X, index="hnsw")
    with pytest.raises(ValueError, match="anytime"):
        hnsw.search(Q, 5, deadline_s=1.0)
    sess = open_index(X)
    with pytest.raises(ValueError, match="deadline_s must be > 0"):
        sess.search(Q, 5, deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline_s must be > 0"):
        sess.search(Q, 5, deadline_s=-1.0)


def test_search_rejects_non_finite_queries():
    X, Q = _data(n=512)
    sess = open_index(X)
    bad = Q.copy()
    bad[2, 5] = np.nan
    with pytest.raises(ValueError, match="NaN/Inf"):
        sess.search(bad, 5)
    bad[2, 5] = np.inf
    with pytest.raises(ValueError, match="NaN/Inf"):
        sess.search(bad, 5)
    with pytest.raises(ValueError, match="numeric"):
        sess.search(np.array([["a"] * X.shape[1]]), 5)


# ------------------------------------------------------------- overload -----
def _service(X, **kw):
    sess = open_index(X, backend="host")
    return sess.serve(slots=4, k=5, **kw)


def test_bounded_queue_reject_new():
    X, Q = _data(n=512)
    svc = _service(X, max_queue=3, admission="reject")
    kept = [svc.submit(Q[i % Q.shape[0]], now=0.0) for i in range(3)]
    turned = [svc.submit(Q[i % Q.shape[0]], now=0.0) for i in range(4)]
    assert all(r.status == "pending" for r in kept)
    assert all(r.status == "shed" and r.resolved and not r.done
               for r in turned)
    assert svc.pending == 3 and svc.shed == 4
    done = svc.drain(now=0.0)
    assert len(done) == 3 and all(r.done for r in done)


def test_bounded_queue_shed_oldest():
    X, Q = _data(n=512)
    svc = _service(X, max_queue=2, admission="shed_oldest")
    a = svc.submit(Q[0], now=0.0)
    b = svc.submit(Q[1], now=0.0)
    c = svc.submit(Q[2], now=0.0)            # evicts a, not c
    assert a.status == "shed" and b.status == "pending" \
        and c.status == "pending"
    assert svc.pending == 2 and svc.shed == 1


def test_queued_timeout_resolves_instead_of_hanging():
    X, Q = _data(n=512)
    svc = _service(X, deadline_s=0.5)
    early = svc.submit(Q[0], now=0.0)
    late = svc.submit(Q[1], now=0.6)
    out = svc.step(now=1.0)                  # early expired, late still live
    assert early.status == "timeout" and early in out
    assert late.done and late in out
    assert svc.timeouts == 1 and svc.completed == 1


def test_per_request_deadline_overrides_service_default():
    X, Q = _data(n=512)
    svc = _service(X, deadline_s=100.0)
    tight = svc.submit(Q[0], now=0.0, deadline_s=0.1)
    out = svc.drain(now=5.0)
    assert tight.status == "timeout" and out == [tight]
    with pytest.raises(ValueError, match="deadline_s"):
        svc.submit(Q[0], deadline_s=0.0)


def test_submit_rejects_non_finite_query():
    X, Q = _data(n=512)
    svc = _service(X)
    bad = Q[0].copy()
    bad[3] = np.inf
    with pytest.raises(ValueError, match="NaN/Inf"):
        svc.submit(bad)
    assert svc.pending == 0


def test_counters_account_for_every_ticket():
    """The §7 invariant: submitted == completed + shed + timeouts +
    failures + pending, through a mix of all outcomes."""
    X, Q = _data(n=512)
    svc = _service(X, max_queue=4, admission="reject", deadline_s=1.0)
    for i in range(8):                        # 4 admitted, 4 shed
        svc.submit(Q[i % Q.shape[0]], now=0.0)
    svc.step(now=0.5)                         # serves 4
    for i in range(3):
        svc.submit(Q[i], now=10.0)            # fresh, expire 2 below
    svc.submit(Q[3], now=10.9)
    svc.step(now=12.0)                        # 3 timeout, 1 served... all 4
    h = svc.health()
    assert h["submitted"] == 12
    assert h["submitted"] == (h["completed"] + h["shed"] + h["timeouts"]
                              + h["failures"] + h["queue_depth"])
    assert h["shed"] == 4 and h["timeouts"] >= 3
    assert h["p99_ewma_s"] is not None and h["p99_ewma_s"] >= 0.0
    # uncertified/partials sub-count COMPLETED requests (they resolve
    # "done"; the certificate/coverage is per-request metadata, so they
    # must never double-count against the terminal-state partition)
    assert 0 <= h["uncertified"] <= h["completed"]
    assert 0 <= h["partials"] <= h["completed"]


def test_device_fault_fails_batch_not_service():
    X, Q = _data(n=512)
    svc = _service(X)
    with faults.inject(fail_search_after=0):
        doomed = svc.submit(Q[0])
        out = svc.step()
    assert doomed.status == "failed" and doomed in out
    assert "FaultError" in doomed.error
    assert svc.failures == 1
    ok = svc.submit(Q[1])                     # the service keeps serving
    svc.step()
    assert ok.done and ok.certified


def test_anytime_partial_served_through_service():
    X, Q = _data()
    sess = open_index(X, backend="host", schedule=_pol())
    svc = sess.serve(slots=4, k=5, deadline_s=0.05)
    with faults.inject(slow_block_s=0.03):
        reqs = [svc.submit(Q[i]) for i in range(4)]
        out = svc.drain()
    served = [r for r in out if r.done]
    assert served and svc.partials >= 1
    partial = [r for r in served if r.coverage is not None
               and r.coverage < 1.0]
    assert partial and all(r.certified is False for r in partial)
    # every withdrawn certificate is counted once in health()
    h = svc.health()
    assert h["uncertified"] == sum(r.certified is False for r in served)
    assert h["uncertified"] >= len(partial)


# ------------------------------------------------------- fault plumbing -----
def test_fault_plan_counts_search_calls():
    plan = FaultPlan(fail_search_after=1)
    faults.check_search(plan)                 # call 0: fine
    with pytest.raises(FaultError):
        faults.check_search(plan)             # call 1: injected failure
    faults.check_search(plan)                 # spent: fine again


def test_fault_env_route(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "slow_block_s=0.25,fail_search_after=2")
    plan = faults.active()
    assert plan == FaultPlan(slow_block_s=0.25, fail_search_after=2)
    monkeypatch.setenv("REPRO_FAULTS", "bogus_knob=1")
    with pytest.raises(ValueError, match="bogus_knob"):
        faults.active()


def test_fault_policy_route_takes_precedence():
    plan = FaultPlan(slow_block_s=0.5)
    pol = SchedulePolicy(faults=plan)
    with faults.inject(slow_block_s=0.125):
        assert faults.active(pol) is plan
        assert faults.active() == FaultPlan(slow_block_s=0.125)
    assert faults.active(pol) is plan
    assert faults.active() is None or isinstance(faults.active(), FaultPlan)


def test_torn_frame_tears_at_most_once():
    plan = FaultPlan(torn_frame_keep=0.5)
    buf = bytes(range(100))
    out1, crash1 = faults.torn_frame(plan, buf)
    assert crash1 and len(out1) == 50
    out2, crash2 = faults.torn_frame(plan, buf)
    assert not crash2 and out2 == buf
