"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; only the dry-run sets the 512-device flag (in its own
process)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def sift_small():
    from repro.vecdata import load_dataset
    return load_dataset("sift", scale=0.05)      # 5k x 128


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
