"""Run property sweeps when hypothesis is installed; skip ONLY those tests
(not their whole module) when it isn't — the container image ships without
hypothesis, and the plain oracle tests in the same files must still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _AnyStrategy:
        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg stand-in: no fixture resolution for strategy params
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
