"""Pallas kernel validation: shape/dtype sweeps + hypothesis vs ref.py
oracles (interpret mode on CPU; same code targets TPU).  CI also runs this
module as an explicit interpret-mode step (REPRO_FORCE_INTERPRET=1)."""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.ops import dco_scan_op, pq_lookup_op


def _seed(*parts) -> int:
    """Stable cross-process seed (builtin hash() is salted by PYTHONHASHSEED,
    which made every pytest process draw different test data)."""
    return zlib.crc32(repr(parts).encode()) % 2 ** 31


@pytest.mark.parametrize("n,q,d1", [
    (256, 128, 128), (300, 17, 130), (64, 8, 96), (1000, 5, 256), (128, 1, 32),
])
@pytest.mark.parametrize("kind", ["lb", "adsampling", "ratio"])
def test_dco_scan_matches_ref(n, q, d1, kind):
    rng = np.random.default_rng(_seed(n, q, d1, kind))
    x = jnp.asarray(rng.standard_normal((n, d1)), jnp.float32)
    qq = jnp.asarray(rng.standard_normal((q, d1)), jnp.float32)
    tau = jnp.asarray(rng.uniform(d1 * 0.5, d1 * 2.5, q), jnp.float32)
    scales = ref.make_dco_scales(kind, d1, 128, D=2 * d1, theta=0.8)
    p1, k1, c1, _ = dco_scan_op(x, qq, tau, scales)
    p2, k2 = ref.dco_scan_ref(x, qq, tau, scales, 128)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-4, atol=1e-3)
    assert (np.asarray(k1) == np.asarray(k2)).all()
    c2 = ref.block_keep_counts_ref(k2, 256)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_dco_scan_nrows_masks_padding():
    """Rows at or beyond nrows never keep and never count — the streaming
    engine relies on this for its last (ragged) corpus block."""
    rng = np.random.default_rng(_seed("nrows"))
    n, q, d1, nvalid = 300, 9, 64, 210
    x = jnp.asarray(rng.standard_normal((n, d1)), jnp.float32)
    qq = jnp.asarray(rng.standard_normal((q, d1)), jnp.float32)
    tau = jnp.asarray(rng.uniform(d1, d1 * 3.0, q), jnp.float32)
    scales = ref.make_dco_scales("lb", d1, 64, D=d1)
    _, k_full, _, _ = dco_scan_op(x, qq, tau, scales, block_d=64)
    _, k_cut, c_cut, _ = dco_scan_op(x, qq, tau, scales, nvalid, block_d=64)
    k_full, k_cut = np.asarray(k_full), np.asarray(k_cut)
    np.testing.assert_array_equal(k_cut[:nvalid], k_full[:nvalid])
    assert (k_cut[nvalid:] == 0).all()
    np.testing.assert_array_equal(np.asarray(c_cut).sum(0), k_cut.sum(0))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("n,q,m,k", [(300, 9, 16, 256), (128, 8, 8, 64),
                                     (65, 3, 4, 16)])
def test_pq_lookup_matches_ref(n, q, m, k, dtype):
    rng = np.random.default_rng(_seed(n, q, m, k))
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.int32)
    lut = jnp.asarray(rng.standard_normal((q, m, k)), dtype)
    a1 = pq_lookup_op(codes, lut)
    a2 = ref.pq_lookup_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 200), q=st.integers(1, 20),
       d1=st.integers(8, 160), seed=st.integers(0, 2**16))
def test_dco_scan_hypothesis(n, q, d1, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d1)), jnp.float32)
    qq = jnp.asarray(rng.standard_normal((q, d1)), jnp.float32)
    tau = jnp.asarray(rng.uniform(0, d1 * 3.0, q), jnp.float32)
    scales = ref.make_dco_scales("lb", d1, 64, D=d1)
    p1, k1, c1, _ = dco_scan_op(x, qq, tau, scales, block_n=64, block_q=32,
                             block_d=64)
    p2, k2 = ref.dco_scan_ref(x, qq, tau, scales, 64)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-4, atol=1e-3)
    assert (np.asarray(k1) == np.asarray(k2)).all()
    np.testing.assert_array_equal(np.asarray(c1),
                                  np.asarray(ref.block_keep_counts_ref(k2, 64)))


def test_dco_scan_keep_semantics():
    """keep=1 rows are exactly those whose final scaled partial <= tau."""
    rng = np.random.default_rng(0)
    n, q, d1 = 128, 4, 64
    x = jnp.asarray(rng.standard_normal((n, d1)), jnp.float32)
    qq = jnp.asarray(rng.standard_normal((q, d1)), jnp.float32)
    tau = jnp.asarray(rng.uniform(20, 150, q), jnp.float32)
    scales = ref.make_dco_scales("lb", d1, 64, D=d1)
    p, k, c, _ = dco_scan_op(x, qq, tau, scales, block_d=64)
    p, k = np.asarray(p), np.asarray(k)
    full = ((np.asarray(x)[:, None] - np.asarray(qq)[None]) ** 2).sum(-1)
    # single dim-block => partial == full, keep == (full <= tau)
    np.testing.assert_allclose(p, full, rtol=1e-4, atol=1e-3)
    assert (k.astype(bool) == (full <= np.asarray(tau)[None, :])).all()
    np.testing.assert_array_equal(np.asarray(c).sum(0), k.sum(0))


@pytest.mark.parametrize("kind", ["lb", "adsampling"])
@pytest.mark.parametrize("n,q,d1,nvalid", [(256, 9, 128, None),
                                           (300, 5, 96, 210)])
def test_dco_scan_dims_matches_ref(n, q, d1, nvalid, kind):
    """The kernel's dims output (rows x dims actually read per block, the
    dims_read_mean telemetry) must match the gating-faithful oracle."""
    rng = np.random.default_rng(_seed("dims", n, q, d1, kind))
    x = jnp.asarray(rng.standard_normal((n, d1)), jnp.float32)
    qq = jnp.asarray(rng.standard_normal((q, d1)), jnp.float32)
    tau = jnp.asarray(rng.uniform(d1 * 0.3, d1 * 1.5, q), jnp.float32)
    scales = ref.make_dco_scales(kind, d1, 64, D=2 * d1)
    _, _, _, dims = dco_scan_op(x, qq, tau, scales, nvalid, block_n=64,
                                block_d=64)
    dims2 = ref.dco_scan_dims_ref(x, qq, tau, scales, 64, 64, nvalid)
    np.testing.assert_allclose(np.asarray(dims), np.asarray(dims2))


@pytest.mark.parametrize("n,q,G,dg,nvalid", [(256, 9, 4, 16, None),
                                             (300, 5, 3, 32, 220),
                                             (128, 8, 1, 64, None)])
def test_dco_scan_grouped_matches_flat_blocks(n, q, G, dg, nvalid):
    """The grouped (PDX, 3D x) kernel entry must agree exactly with the flat
    kernel run at block_d == dg: same dim-block boundaries, same gating,
    same accumulation order — partial, keep, counts and dims all match."""
    from repro.kernels.ops import dco_scan_grouped_op

    d1 = G * dg
    rng = np.random.default_rng(_seed("grouped", n, q, G, dg))
    x = jnp.asarray(rng.standard_normal((n, d1)), jnp.float32)
    qq = jnp.asarray(rng.standard_normal((q, d1)), jnp.float32)
    tau = jnp.asarray(rng.uniform(d1 * 0.3, d1 * 1.5, q), jnp.float32)
    scales = ref.make_dco_scales("lb", d1, dg, D=d1)
    p0, k0, c0, a0 = dco_scan_op(x, qq, tau, scales, nvalid, block_n=64,
                                 block_d=dg)
    xg = jnp.moveaxis(x.reshape(n, G, dg), 1, 0)
    qg = jnp.moveaxis(qq.reshape(q, G, dg), 1, 0)
    widths = jnp.full((G,), dg, jnp.float32)
    p1, k1, c1, a1 = dco_scan_grouped_op(xg, qg, tau, scales, widths, nvalid,
                                         block_n=64)
    np.testing.assert_allclose(np.asarray(p0), np.asarray(p1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k1))
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    np.testing.assert_allclose(np.asarray(a0), np.asarray(a1))
