"""Sharding-rule tests: every spec divides its dim for every architecture on
the production mesh shapes (no devices needed — rules only read mesh.shape)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_arch
from repro.configs import sharding as SH
from repro.models import build_model


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axsize(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("mesh,fsdp", [(POD, ("data",)),
                                       (MULTI, ("pod", "data"))])
def test_param_specs_divisible(arch, mesh, fsdp):
    cfg = get_arch(arch)
    api = build_model(cfg)
    shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    specs = SH.param_specs(shapes, mesh, fsdp=fsdp)

    def check(path, sds, spec):
        assert len(spec) <= len(sds.shape), (path, sds.shape, spec)
        for i, axes in enumerate(spec):
            if axes is None:
                continue
            assert sds.shape[i] % _axsize(mesh, axes) == 0, \
                (arch, path, sds.shape, spec)

    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_p = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_s) == len(flat_p)
    for (path, sds), spec in zip(flat_s, flat_p):
        check(jax.tree_util.keystr(path), sds, spec)


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-v3-671b"])
def test_big_tensors_are_sharded(arch):
    """The big 2D weights must NOT replicate on the pod mesh."""
    cfg = get_arch(arch)
    api = build_model(cfg)
    shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    specs = SH.param_specs(shapes, POD, fsdp=("data",))
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_p = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    worst_repl = 0
    for (path, sds), spec in zip(flat_s, flat_p):
        n = 1
        for d in sds.shape:
            n *= d
        if n < 1_000_000:
            continue
        sharded = any(a is not None for a in spec)
        assert sharded, (jax.tree_util.keystr(path), sds.shape)


def test_cache_specs_long_context():
    """batch=1 long-context cache shards the sequence axis instead."""
    import jax.numpy as jnp
    cache = {"k": jax.ShapeDtypeStruct((32, 1, 524288, 8, 128), jnp.bfloat16)}
    specs = SH.cache_specs(cache, POD, dp=("data",))
    assert specs["k"][2] in (("data",), "data"), specs["k"]
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16)}
    specs = SH.cache_specs(cache, POD, dp=("data",))
    assert specs["k"][1] in (("data",), "data")
