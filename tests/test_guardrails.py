"""Guardrail layer: drift sentinel, online audits, circuit breaker (§9).

The contracts:

1. **The sentinel separates** — in-distribution batches score near 0, the
   spectrum-shift OOD batches (``make_ood_queries``) score near 1, and the
   drift-scenario generator produces streams whose profile the sentinel
   tracks.

2. **The breaker's open state is the certified full scan** — a tripped
   breaker serves results bit-identical to an FDScanning session over the
   same corpus, on both backends.

3. **Closed-state serving is untouched** — with guardrails armed but not
   tripped, ids/dists are bit-identical to an unguarded session (audits
   shadow, never substitute).

4. **State-machine edges are deterministic** under ``testing.faults``
   drift/audit overrides: trips need drift AND evidence, flaps are bounded
   by ``min_dwell``, a failed canary re-opens, recovery re-promotes.
"""
import numpy as np
import pytest

from repro.api import (GuardrailConfig, SchedulePolicy, SearchSession,
                       open_index)
from repro.core.engine import (EXTRA_AUDIT_RECALL, EXTRA_BREAKER_STATE,
                               EXTRA_DRIFT_SCORE)
from repro.core.guardrails import DriftSentinel, Guardrail, _sample_recall
from repro.testing import faults
from repro.vecdata.synthetic import make_drift_scenario, make_ood_queries


def _corpus(n=1500, d=48, seed=5):
    """Anisotropic corpus (power-law spectrum) under a random rotation —
    the regime where the principal-split sentinel has signal."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    X *= (np.arange(1, d + 1, dtype=np.float32) ** -0.7)
    R, _ = np.linalg.qr(rng.standard_normal((d, d)).astype(np.float32))
    return np.ascontiguousarray(X @ R, np.float32)


def _id_queries(X, nq=16, seed=11):
    rng = np.random.default_rng(seed)
    idx = rng.choice(X.shape[0], nq, replace=False)
    return X[idx] + 0.01 * rng.standard_normal((nq, X.shape[1])).astype(np.float32)


def _pol(**kw):
    kw.setdefault("d1", 16)
    kw.setdefault("query_chunk", 8)
    kw.setdefault("row_block", 256)
    kw.setdefault("block_capacity", 32)
    return SchedulePolicy(**kw)


# ------------------------------------------------------------- sentinel -----
def test_sentinel_separates_id_from_ood():
    X = _corpus()
    s = DriftSentinel.fit(X, r=8, seed=0)
    sid = s.score(_id_queries(X))
    sood = s.score(make_ood_queries(X, 16, severity=1.0))
    assert 0.0 <= sid <= 1.0 and 0.0 <= sood <= 1.0
    assert sid < 0.2 < 0.5 < sood
    # severity interpolates monotonically enough to rank the extremes
    smid = s.score(make_ood_queries(X, 16, severity=0.5))
    assert sid < smid < 1.0


def test_sentinel_catches_scale_drift():
    X = _corpus()
    s = DriftSentinel.fit(X, r=8, seed=0)
    Q = _id_queries(X)
    assert s.score(5.0 * Q) > 0.35           # norm-deviation term fires


def test_drift_scenario_shapes_and_profiles():
    X = _corpus()
    for scen in ("gradual", "sudden", "recovering"):
        stream = make_drift_scenario(X, 8, 9, scenario=scen)
        assert len(stream) == 9
        assert all(b.shape == (8, X.shape[1]) for b in stream)
    s = DriftSentinel.fit(X, r=8, seed=0)
    sudden = [s.score(b) for b in make_drift_scenario(X, 16, 9,
                                                      scenario="sudden")]
    assert max(sudden[:3]) < 0.35 < min(sudden[3:])
    recov = [s.score(b) for b in make_drift_scenario(X, 16, 9,
                                                     scenario="recovering")]
    assert recov[4] > 0.5 and max(recov[0], recov[-1]) < 0.35
    with pytest.raises(ValueError, match="scenario"):
        make_drift_scenario(X, 8, 9, scenario="chaotic")
    with pytest.raises(ValueError, match="n_batches"):
        make_drift_scenario(X, 8, 0)


def test_sample_recall():
    a = np.array([[1, 2, 3], [4, 5, 6]])
    assert _sample_recall(a, a, 3) == 1.0
    b = np.array([[1, 2, 9], [4, 5, 6]])
    assert _sample_recall(b, a, 3) == pytest.approx(5 / 6)


# ------------------------------------------------- breaker on real drift ----
@pytest.mark.parametrize("backend", ["host", "jax"])
def test_breaker_trips_on_ood_and_open_matches_fdscan(backend):
    X = _corpus()
    gcfg = GuardrailConfig(min_dwell=2, audit_rate=0.25, audit_batch=2)
    sess = open_index(X, method="PDScanning", backend=backend,
                      schedule=_pol(guardrails=gcfg))
    ref = open_index(X, method="FDScanning", backend=backend,
                     schedule=_pol())
    assert sess.guardrails()["state"] == "closed"
    r0 = sess.search(_id_queries(X), 10)
    assert r0.stats.extra[EXTRA_BREAKER_STATE] == "closed"
    assert r0.stats.extra[EXTRA_DRIFT_SCORE] < 0.35
    ood = make_ood_queries(X, 16, severity=1.0)
    # the host screen completes every survivor exactly, so OOD gives no
    # uncertified/audit evidence there — inject the audit divergence the
    # jax path produces naturally (capacity overflow / lost neighbors)
    chaos = (faults.inject(audit_recall=0.5) if backend == "host"
             else faults.inject())
    with chaos:
        for _ in range(8):
            res = sess.search(ood, 10)
            if res.stats.extra[EXTRA_BREAKER_STATE] == "open":
                break
    g = sess.guardrails()
    assert g["state"] == "open" and g["demoted_batches"] >= 1
    assert any(t["to"] == "open" for t in g["transitions"])
    # pinned: the OPEN breaker's served results are bit-identical to an
    # FDScanning session (same rotated coords, same certified scan body)
    ro = sess.search(ood, 10)
    rf = ref.search(ood, 10)
    assert ro.stats.extra[EXTRA_BREAKER_STATE] == "open"
    assert np.array_equal(ro.ids, rf.ids)
    assert np.array_equal(ro.dists, rf.dists)


@pytest.mark.parametrize("backend", ["host", "jax"])
def test_closed_state_is_bit_identical_to_unguarded(backend):
    X = _corpus()
    Q = _id_queries(X)
    gcfg = GuardrailConfig(audit_rate=0.5, audit_batch=1)   # audits fire
    guarded = open_index(X, method="PDScanning", backend=backend,
                         schedule=_pol(guardrails=gcfg))
    bare = open_index(X, method="PDScanning", backend=backend,
                      schedule=_pol())
    for _ in range(3):
        rg = guarded.search(Q, 10)
        rb = bare.search(Q, 10)
        assert rg.stats.extra[EXTRA_BREAKER_STATE] == "closed"
        assert np.array_equal(rg.ids, rb.ids)
        assert np.array_equal(rg.dists, rb.dists)
    assert guarded.guardrails()["audits"] >= 1       # audits DID run


def test_closed_state_identical_ivf_host():
    X = _corpus()
    Q = _id_queries(X)
    gcfg = GuardrailConfig(audit_rate=0.5, audit_batch=1)
    guarded = open_index(X, index="ivf", method="PDScanning", backend="host",
                         schedule=_pol(guardrails=gcfg))
    bare = open_index(X, index="ivf", method="PDScanning", backend="host",
                      schedule=_pol())
    rg, rb = guarded.search(Q, 10), bare.search(Q, 10)
    assert np.array_equal(rg.ids, rb.ids)
    assert np.array_equal(rg.dists, rb.dists)


# ------------------------------------------- state-machine edges (faults) ---
def _scripted(X, **gkw):
    """Host session with every pacing knob at 1 except where overridden —
    the fault-override tests script drift/audit per batch."""
    gkw.setdefault("min_dwell", 1)
    gkw.setdefault("trip_after", 1)
    gkw.setdefault("promote_after", 1)
    gkw.setdefault("audit_rate", 1.0)
    gkw.setdefault("audit_batch", 1)
    # cost_ratio is measured wall clock — park its ceiling out of reach so
    # timing noise on a tiny corpus can't fabricate trip evidence
    gkw.setdefault("cost_ceiling", 100.0)
    return open_index(X, method="PDScanning", backend="host",
                      schedule=_pol(guardrails=GuardrailConfig(**gkw)))


def test_trip_needs_drift_and_evidence():
    X = _corpus()
    Q = _id_queries(X)
    # drift without evidence: audits are clean (recall 1.0), so no trip
    sess = _scripted(X)
    with faults.inject(drift_score=0.9, audit_recall=1.0):
        for _ in range(4):
            sess.search(Q, 10)
    assert sess.guardrails()["state"] == "closed"
    # evidence without drift: failing audits alone never demote
    sess = _scripted(X)
    with faults.inject(drift_score=0.0, audit_recall=0.2):
        for _ in range(4):
            sess.search(Q, 10)
    assert sess.guardrails()["state"] == "closed"
    # both: trips
    sess = _scripted(X)
    with faults.inject(drift_score=0.9, audit_recall=0.2):
        for _ in range(4):
            sess.search(Q, 10)
    assert sess.guardrails()["state"] == "open"


def test_flaps_bounded_by_min_dwell():
    """Alternating 2-batch id/ood bursts: serving-mode transitions (into or
    out of 'closed') must be at least min_dwell batches apart."""
    X = _corpus()
    Q = _id_queries(X)
    sess = _scripted(X, min_dwell=3)
    for burst in range(10):
        drift = 0.9 if burst % 2 else 0.0
        with faults.inject(drift_score=drift, audit_recall=0.2 if drift else 1.0):
            for _ in range(2):
                sess.search(Q, 10)
    g = sess.guardrails()
    flips = [t["batch"] for t in g["transitions"]
             if (t["from"] == "closed") != (t["to"] == "closed")]
    assert all(b - a >= 3 for a, b in zip(flips, flips[1:]))
    assert g["batches"] == 20


def test_canary_failure_reopens():
    X = _corpus()
    Q = _id_queries(X)
    sess = _scripted(X)
    g = sess.backend.guardrail
    g.force_state("half_open")
    with faults.inject(drift_score=0.0, audit_recall=0.0):
        res = sess.search(Q, 10)
    # the half-open batch itself was served certified...
    assert res.stats.extra[EXTRA_BREAKER_STATE] == "half_open"
    # ...and the failed canary re-opened immediately
    assert g.state == "open"
    assert any(t["to"] == "open" and "canary" in t["reason"]
               for t in g.transitions)


def test_drift_then_recover_repromotes():
    X = _corpus()
    Q = _id_queries(X)
    sess = _scripted(X, min_dwell=2, promote_after=2)
    with faults.inject(drift_score=0.95, audit_recall=0.0):
        for _ in range(4):
            sess.search(Q, 10)
    assert sess.guardrails()["state"] == "open"
    with faults.inject(drift_score=0.0, audit_recall=1.0):
        for _ in range(10):
            res = sess.search(Q, 10)
    g = sess.guardrails()
    assert g["state"] == "closed"
    assert g["audit_recall"] > 0.99          # EWMA converging back to 1.0
    assert res.stats.extra[EXTRA_AUDIT_RECALL] > 0.99
    seq = [(t["from"], t["to"]) for t in g["transitions"]]
    assert ("open", "half_open") in seq and ("half_open", "closed") in seq


def test_force_state_validates():
    X = _corpus(n=400)
    sess = _scripted(X)
    g = sess.backend.guardrail
    with pytest.raises(ValueError, match="breaker state"):
        g.force_state("bogus")
    g.force_state("open")
    assert sess.guardrails()["state"] == "open"


# --------------------------------------------------- sampling determinism ---
def test_audit_sampling_is_deterministic():
    X = _corpus(n=400)
    a = Guardrail(GuardrailConfig(seed=3), _Method(X), "host")
    b = Guardrail(GuardrailConfig(seed=3), _Method(X), "host")
    for _ in range(5):
        assert a._take_audit(16) == b._take_audit(16)
        assert np.array_equal(a._sample(16, 4), b._sample(16, 4))
        a.batches += 1
        b.batches += 1
    # replaying a batch index reproduces its picks exactly
    a.batches = 0
    s0 = a._sample(16, 4)
    a.batches = 1
    a._sample(16, 4)
    a.batches = 0
    assert np.array_equal(a._sample(16, 4), s0)


def test_audit_accumulator_batches_shadow_calls():
    X = _corpus(n=400)
    g = Guardrail(GuardrailConfig(audit_rate=1 / 64, audit_batch=8),
                  _Method(X), "host")
    taken = [g._take_audit(16) for _ in range(64)]
    # 64 batches x 16 q / 64 = 16 audited queries, flushed in groups of 8
    assert sum(taken) == 16
    assert sorted(set(taken)) == [0, 8]


class _Method:
    """Minimal stand-in exposing what Guardrail needs."""

    name = "PDScanning"

    def __init__(self, X):
        self.state = {"X": X}


# ----------------------------------------------------------- arming rules ---
def test_hnsw_rejects_guardrails():
    X = _corpus(n=400)
    with pytest.raises(ValueError, match="HNSW"):
        open_index(X, index="hnsw", backend="host",
                   schedule=SchedulePolicy(guardrails=GuardrailConfig()))


def test_fdscan_is_silently_unarmed():
    X = _corpus(n=400)
    sess = open_index(X, method="FDScanning", backend="host",
                      schedule=SchedulePolicy(guardrails=GuardrailConfig()))
    assert sess.guardrails() is None
    res = sess.search(_id_queries(X), 10)
    assert EXTRA_BREAKER_STATE not in res.stats.extra


def test_guardrails_true_means_defaults():
    X = _corpus(n=400)
    sess = open_index(X, method="PDScanning", backend="host",
                      schedule=_pol(guardrails=True))
    g = sess.backend.guardrail
    assert g is not None and g.cfg == GuardrailConfig()
    assert sess.guardrails()["state"] == "closed"


def test_deadline_calls_bypass_guardrail():
    X = _corpus()
    sess = open_index(X, method="PDScanning", backend="host",
                      schedule=_pol(guardrails=GuardrailConfig()))
    res = sess.search(_id_queries(X), 10, deadline_s=1e3)
    assert EXTRA_BREAKER_STATE not in res.stats.extra
    assert sess.guardrails()["batches"] == 0


def test_service_health_reports_breaker():
    X = _corpus()
    sess = open_index(X, method="PDScanning", backend="host",
                      schedule=_pol(guardrails=GuardrailConfig()))
    svc = sess.serve(slots=4, k=5)
    for q in _id_queries(X, 4):
        svc.submit(q)
    svc.drain()
    h = svc.health()
    assert h["breaker_state"] == "closed"
    assert 0.0 <= h["drift_score"] <= 1.0
    assert h["audit_recall"] == pytest.approx(1.0)
    assert h["demoted_batches"] == 0


# ---------------------------------------------------------- non-finite add --
def test_add_rejects_non_finite_rows():
    X = _corpus(n=400)
    sess = open_index(X, backend="host")
    bad = np.ones((3, X.shape[1]), np.float32)
    bad[1, 5] = np.nan
    with pytest.raises(ValueError, match="NaN/Inf"):
        sess.add(bad)
    assert sess.n == 400                     # nothing was applied
    sess.add(np.ones((2, X.shape[1]), np.float32))
    assert sess.n == 402
