"""Oracle equivalence tests for the nontrivial layers:
  * SSD chunked scan == naive sequential recurrence (+ hypothesis sweep)
  * SSD decode step == one step of the naive recurrence
  * MLA absorbed decode == expanded attention on the same prefix
  * MoE capacity-unbounded == dense top-k routing reference
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import smoke_config
from repro.models import mamba2 as M
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.layers import CDTYPE

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


def _ssd_inputs(rng, B, S, H, P, N):
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(np.log(rng.uniform(0.5, 4.0, H)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_naive(chunk):
    rng = np.random.default_rng(0)
    x, dt, A, Bm, Cm = _ssd_inputs(rng, 2, 16, 3, 4, 5)
    y1, h1 = M.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, h2 = M.ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 3), nc=st.integers(1, 4), H=st.integers(1, 4),
       P=st.integers(1, 6), N=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_ssd_chunked_hypothesis(B, nc, H, P, N, seed):
    rng = np.random.default_rng(seed)
    S = nc * 8
    x, dt, A, Bm, Cm = _ssd_inputs(rng, B, S, H, P, N)
    y1, h1 = M.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y2, h2 = M.ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-3,
                               atol=5e-3)


def test_mamba_prefill_then_decode_matches_full():
    """forward(S+1) == forward(S) -> decode(1) via carried state."""
    cfg = smoke_config("mamba2-130m")
    p = M.init_mamba(KEY, cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 33
    u = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    # full forward over S (chunk must divide: use naive-compatible path)
    out_full = None
    # run prefill on first S-1, then decode the last token
    y_pre, (h, conv) = M.mamba_forward(p, cfg, u[:, : S - 1], return_state=True)
    y_dec, _ = M.mamba_decode(p, cfg, u[:, S - 1 :], (h, conv))
    # reference: same via naive full pass
    cfg_big = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=S))
    y_all = M.mamba_forward(p, cfg_big, u)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_all[:, -1]),
                               rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------


def test_mla_absorbed_decode_equals_expanded():
    cfg = smoke_config("deepseek-v2-236b")
    p = MLA.init_mla(KEY, cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 9
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    out_full, (c_kv, k_rope) = MLA.mla_forward(p, cfg, x)
    # decode path: prefix S-1 into the cache, decode token S-1
    cache = MLA.init_mla_cache(cfg, B, S, dtype=jnp.float32)
    out_dec = None
    for t in range(S):
        out_dec, cache = MLA.mla_decode(p, cfg, x[:, t : t + 1], cache, t + 1)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0], np.float32),
                               np.asarray(out_full[:, -1], np.float32),
                               rtol=0.08, atol=0.08)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _dense_moe_reference(params, cfg, x):
    """sum over top-k experts of gate * expert(x) — no capacity drops."""
    mc = cfg.moe
    T, D = x.shape
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, mc.top_k)
    vals = vals / vals.sum(-1, keepdims=True)
    out = jnp.zeros((T, D), jnp.float32)
    for e in range(mc.n_experts):
        gate_e = jnp.where((idx == e).any(-1),
                           jnp.where(idx == e, vals, 0.0).sum(-1), 0.0)
        xc = x.astype(CDTYPE)
        h = (jax.nn.silu(xc @ params["wg"][e].astype(CDTYPE))
             * (xc @ params["wu"][e].astype(CDTYPE)))
        y = (h @ params["wd"][e].astype(CDTYPE)).astype(jnp.float32)
        out = out + gate_e[:, None] * y
    return out


def test_moe_matches_dense_reference_when_uncapped():
    cfg = smoke_config("deepseek-v2-236b")
    mc = dataclasses.replace(cfg.moe, capacity_factor=100.0)  # no drops
    cfg = dataclasses.replace(cfg, moe=mc)
    p = MOE.init_moe(KEY, cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    out, aux = MOE.moe_forward(p, cfg, x)            # local path
    if mc.n_shared:
        sp = p["shared"]
        xc = x.astype(CDTYPE)
        h = jax.nn.silu(xc @ sp["wg"].astype(CDTYPE)) * (xc @ sp["wu"].astype(CDTYPE))
        out = out - (h @ sp["wd"].astype(CDTYPE)).astype(x.dtype)
    ref = _dense_moe_reference(p, cfg, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               np.asarray(ref), rtol=0.05, atol=0.05)


def test_moe_capacity_drops_bounded():
    """With cf=1.0, dropped fraction is bounded and aux loss is finite."""
    cfg = smoke_config("deepseek-v2-236b")
    p = MOE.init_moe(KEY, cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)
    out, aux = MOE.moe_forward(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))
