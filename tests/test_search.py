"""Index-level tests: IVF + HNSW recall, DCO-accelerated construction,
dynamic inserts, serving engine + DCO attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import QueryBatch, ScanStats, make_schedule
from repro.core.methods import make_method
from repro.search.hnsw import HNSWIndex
from repro.search.ivf import IVFIndex
from repro.vecdata.synthetic import recall_at_k

K = 10


def test_ivf_recall_vs_nprobe(sift_small):
    ds = sift_small
    idx = IVFIndex(n_list=64).build(ds.X)
    m = make_method("FDScanning").fit(ds.X)
    batch = QueryBatch.create(m, ds.Q[:16])
    gt, _ = ds.ground_truth(K)
    recs = []
    for nprobe in (2, 16, 64):
        found = [idx.search(m, batch, qi, K, nprobe)[1]
                 for qi in range(16)]
        recs.append(recall_at_k(np.array(found), gt[:16]))
    assert recs[-1] == 1.0                     # all partitions == brute force
    assert recs[0] <= recs[1] <= recs[2]


def test_ivf_dco_methods_agree_at_full_probe(sift_small):
    ds = sift_small
    idx = IVFIndex(n_list=32).build(ds.X)
    gt, _ = ds.ground_truth(K)
    for name in ("PDScanning+", "ADSampling", "DDCres"):
        m = make_method(name).fit(ds.X)
        stats = ScanStats()
        batch = QueryBatch.create(m, ds.Q[:8], stats=stats)
        found = [idx.search(m, batch, qi, K, 32)[1]
                 for qi in range(8)]
        rec = recall_at_k(np.array(found), gt[:8])
        assert rec >= 0.95, (name, rec)
        assert stats.pruning_ratio > 0.2


def test_ivf_insert(sift_small):
    ds = sift_small
    half = ds.n // 2
    idx = IVFIndex(n_list=32).build(ds.X[:half])
    m = make_method("PDScanning").fit(ds.X)
    cent_m = make_method("PDScanning").fit(idx.centroids)
    idx.insert(np.arange(half, ds.n), ds.X[half:], method=cent_m)
    assert idx.n == ds.n
    batch = QueryBatch.create(m, ds.Q[:8])
    gt, _ = ds.ground_truth(K)
    found = [idx.search(m, batch, qi, K, 32)[1] for qi in range(8)]
    assert recall_at_k(np.array(found), gt[:8]) == 1.0


@pytest.mark.slow
def test_hnsw_build_and_search():
    from repro.vecdata import load_dataset
    ds = load_dataset("sift", scale=0.02)       # 2k vectors
    sched = make_schedule(ds.dim)
    m = make_method("PDScanning+").fit(ds.X)
    idx = HNSWIndex(m=8, ef_construction=40).build(ds.X, method=m,
                                                   schedule=sched)
    batch = QueryBatch.create(m, ds.Q[:10], sched)
    gt, _ = ds.ground_truth(K)
    found = [idx.search(m, batch, qi, K, 90)[1]
             for qi in range(10)]
    rec = recall_at_k(np.array(found), gt[:10])
    assert rec >= 0.75, rec


def test_distributed_topk_subprocess():
    """shard_map engine == single-device engine (8 fake devices)."""
    import subprocess, sys, os
    code = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.vecdata import load_dataset
from repro.core.methods import make_method
from repro.core.jax_engine import DcoEngineConfig, build_device_state, two_stage_topk, make_distributed_topk
from repro.launch.mesh import make_host_mesh
from jax.sharding import NamedSharding, PartitionSpec as P
ds = load_dataset("sift", scale=0.04)
m = make_method("PDScanning+").fit(ds.X)
cfg = DcoEngineConfig(kind="lb", d1=48, k=10, capacity=512, query_chunk=8)
W = jnp.asarray(m.state["pca"]["W"]); Q = jnp.asarray(ds.Q[:8]) @ W
st = build_device_state(m, cfg.d1)
d0, i0, _ = two_stage_topk(st, Q[:, :cfg.d1], Q[:, cfg.d1:], cfg)
mesh = make_host_mesh(4, 2)
xr = np.asarray(m.state["Xrot"], np.float32)
sh = NamedSharding(mesh, P(("data","model")))
a = [jax.device_put(v, sh) for v in (xr[:, :cfg.d1], xr[:, cfg.d1:], (xr[:, :cfg.d1]**2).sum(1), (xr[:, cfg.d1:]**2).sum(1))]
fn = make_distributed_topk(mesh, cfg)
dd, ii, ss, dm = fn(*a, Q[:, :cfg.d1], Q[:, cfg.d1:], {})
assert float(np.abs(np.sort(np.array(dd),1) - np.sort(np.array(d0),1)).max()) < 1e-3
ss = np.array(ss)
assert (ss > 0).all() and (ss <= ds.n).all()      # real completions, all shards
assert (np.array(dm) > np.array(dd)[:, -1]).all() # exactness certified
# facade mesh path must serve rules with per-query extras / rule scalars
from repro.api import open_index, SchedulePolicy
from repro.vecdata.synthetic import recall_at_k
gt, _ = ds.ground_truth(10)
pol = SchedulePolicy(d1=48, capacity=512, query_chunk=8)
for name in ("DDCres", "DADE"):
    sess = open_index(ds.X, index="flat", method=name, backend="jax",
                      schedule=pol, mesh=mesh)
    res = sess.search(ds.Q[:13], 10)          # ragged through the mesh
    assert recall_at_k(res.ids, gt[:13]) >= 0.95, name
print("DIST_OK")
'''
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_OK" in r.stdout, r.stderr[-2000:]


class _ShapeOnlyMesh:
    """Enough mesh for make_distributed_topk's build-time validation (which
    only reads ``mesh.shape``) — no devices needed to prove the fail-fast."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_distributed_topk_validates_shard_alignment():
    """The mesh certificate sharp edge fails at BUILD time: shard sizes
    that don't divide evenly, or aren't a row_block multiple (phantom
    padding rows would weaken the streaming dropped-estimate certificate),
    raise a named ValueError instead of silently degrading."""
    from repro.core.jax_engine import DcoEngineConfig, make_distributed_topk
    mesh = _ShapeOnlyMesh({"data": 4, "model": 2})
    cfg = DcoEngineConfig(kind="lb", d1=16, k=10, row_block=64)
    with pytest.raises(ValueError, match="do not shard evenly"):
        make_distributed_topk(mesh, cfg, n_rows=903)     # 903 % 8 != 0
    with pytest.raises(ValueError, match="row_block"):
        make_distributed_topk(mesh, cfg, n_rows=8 * 96)  # 96 % 64 != 0
    # success paths need a real mesh (shard_map construction checks it)
    from repro.launch.mesh import make_host_mesh
    real = make_host_mesh(1, 1)
    # aligned rows build fine with the stream engine
    make_distributed_topk(real, cfg, n_rows=128)
    # the two_stage engine has no streaming certificate: only even split
    # is required (no error for a 96-row shard under row_block=64)
    make_distributed_topk(real, cfg, n_rows=96, engine="two_stage")
    # n_rows=None preserves the old caller-beware behavior
    make_distributed_topk(real, cfg, n_rows=None)


def test_aligned_row_block_is_largest_safe_divisor():
    from repro.core.jax_engine import _aligned_row_block
    assert _aligned_row_block(96, 64) == 48      # largest divisor <= 64
    assert _aligned_row_block(128, 64) == 64     # already aligned
    assert _aligned_row_block(97, 64) == 1       # prime shard: worst case
    assert _aligned_row_block(10, 64) == 10      # block larger than shard
    for per_shard, rb in ((96, 64), (1000, 48), (7, 3)):
        got = _aligned_row_block(per_shard, rb)
        assert per_shard % got == 0 and 1 <= got <= rb


def test_dco_attention_close_to_exact():
    from repro.serving.dco_attention import (dco_decode_attention,
                                             exact_decode_attention,
                                             fit_key_rotation)
    rng = np.random.default_rng(0)
    B, S, Hkv, G, hd = 2, 256, 2, 2, 32
    H = Hkv * G
    # keys with decaying spectrum so PCA screening has signal
    scale = (np.arange(1, hd + 1) ** -0.7).astype(np.float32)
    k = (rng.standard_normal((B, S, Hkv, hd)) * scale).astype(np.float32)
    v = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    q = (rng.standard_normal((B, H, hd)) * scale).astype(np.float32)
    rot = jnp.asarray(fit_key_rotation(k.reshape(-1, hd)))
    k_rot = jnp.einsum("bshd,de->bshe", jnp.asarray(k), rot)
    exact = exact_decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), S)
    # q must be rotated consistently inside dco fn (it rotates internally)
    approx_hi = dco_decode_attention(jnp.asarray(q), k_rot, jnp.asarray(v),
                                     rot, S, d1=8, cap=S)      # cap=S: exact
    np.testing.assert_allclose(np.asarray(approx_hi), np.asarray(exact),
                               rtol=2e-2, atol=2e-2)
    approx = dco_decode_attention(jnp.asarray(q), k_rot, jnp.asarray(v),
                                  rot, S, d1=16, cap=96)
    err = np.abs(np.asarray(approx) - np.asarray(exact)).max()
    assert err < 0.25, err


def test_serving_engine_completes():
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving.engine import Request, ServingEngine
    cfg = smoke_config("olmo-1b")
    api = build_model(cfg, remat="none")
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4), max_new=3)
            for i in range(5)]
    eng = ServingEngine(api, slots=2, max_len=32)
    out = eng.run(params, reqs)
    assert sorted(out) == [0, 1, 2, 3, 4]
    assert all(len(v) == 3 for v in out.values())
