"""Facade tests: host<->jax parity, batched-vs-loop equivalence, dynamic
add()+search() on both host indexes, ragged device batches, save/load."""
import os

import numpy as np
import pytest

from repro.api import (METHODS, SchedulePolicy, SearchSession, open_index)
from repro.vecdata.synthetic import recall_at_k

K = 10


@pytest.mark.parametrize("name", ["FDScanning", "PDScanning+"])
def test_host_jax_parity_exact_methods(name, sift_small):
    """Exact methods must return IDENTICAL top-k on both backends."""
    ds = sift_small
    pol = SchedulePolicy(d1=48, query_chunk=8)
    rh = open_index(ds.X, index="flat", method=name,
                    backend="host", schedule=pol).search(ds.Q[:8], K)
    rj = open_index(ds.X, index="flat", method=name,
                    backend="jax", schedule=pol).search(ds.Q[:8], K)
    assert rh.backend == "host" and rj.backend == "jax"
    np.testing.assert_array_equal(np.sort(rh.ids, 1), np.sort(rj.ids, 1))
    np.testing.assert_allclose(np.sort(rh.dists, 1), np.sort(rj.dists, 1),
                               rtol=1e-3, atol=1e-2)


def test_jax_ragged_batch_matches_aligned(sift_small):
    """Regression: nq not a multiple of query_chunk used to crash/drop rows
    in two_stage_topk's reshape; the engine now pads and masks."""
    ds = sift_small
    sess = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                      schedule=SchedulePolicy(d1=48, query_chunk=4))
    r_full = sess.search(ds.Q[:8], K)           # aligned: 8 % 4 == 0
    r_ragged = sess.search(ds.Q[:7], K)         # ragged: 7 % 4 != 0
    assert r_ragged.ids.shape == (7, K)
    np.testing.assert_array_equal(r_ragged.ids, r_full.ids[:7])


def test_two_stage_topk_ragged_direct(sift_small):
    """Engine-level regression for the reshape crash, all decision kinds."""
    import jax.numpy as jnp
    from repro.core.jax_engine import (DcoEngineConfig, build_device_state,
                                       two_stage_topk)
    from repro.core.methods import make_method

    ds = sift_small
    m = make_method("PDScanning+").fit(ds.X)
    cfg = DcoEngineConfig(kind="lb", d1=48, k=K, capacity=512, query_chunk=8)
    st = build_device_state(m, cfg.d1)
    Q = jnp.asarray(ds.Q[:13]) @ jnp.asarray(m.state["pca"]["W"])  # 13 % 8 != 0
    d, i, s = two_stage_topk(st, Q[:, :cfg.d1], Q[:, cfg.d1:], cfg)
    assert d.shape == (13, K) and i.shape == (13, K) and s.shape == (13,)
    gt, _ = ds.ground_truth(K)
    assert recall_at_k(np.asarray(i), gt[:13]) == 1.0


def test_batched_equals_query_loop(sift_small):
    """One batched search(Q) == per-query searches, host and jax."""
    ds = sift_small
    for backend in ("host", "jax"):
        sess = open_index(ds.X, index="flat", method="PDScanning+",
                          backend=backend, schedule=SchedulePolicy(d1=48))
        batched = sess.search(ds.Q[:6], K)
        for qi in range(6):
            single = sess.search(ds.Q[qi:qi + 1], K)
            np.testing.assert_array_equal(single.ids[0], batched.ids[qi]), backend


@pytest.mark.parametrize("index", ["ivf", "hnsw"])
def test_add_then_search(index, sift_small):
    """Dynamic adds: build on 60%, add 40%, search finds inserted rows."""
    ds = sift_small
    n0 = int(ds.n * 0.6)
    params = {"n_list": 32} if index == "ivf" else {"m": 8, "ef_construction": 48}
    sess = open_index(ds.X[:n0], index=index, method="PDScanning+",
                      index_params=params)
    sess.add(ds.X[n0:])
    assert sess.n == ds.n
    gt, _ = ds.ground_truth(K)
    res = sess.search(ds.Q[:8], K, nprobe=32, ef=128)
    rec = recall_at_k(res.ids, gt[:8])
    if index == "ivf":
        assert rec == 1.0          # all partitions probed == brute force
    else:
        # graph recall at 5k scale varies with the (per-process) synthetic
        # draw; the contract under test is that adds are linked and served
        assert rec >= 0.5, rec
    # at least one inserted id must be reachable
    assert (res.ids >= n0).any()


def test_every_method_serves_through_facade(sift_small):
    """All 8 paper methods open and search on the host backend with sane
    recall; exact ones at 1.0 (flat index == brute force)."""
    ds = sift_small
    gt, _ = ds.ground_truth(K)
    for name in METHODS:
        sess = open_index(ds.X, index="flat", method=name)
        res = sess.search(ds.Q[:4], K)
        rec = recall_at_k(res.ids, gt[:4])
        if sess.method.exact:
            assert rec == 1.0, (name, rec)
        else:
            assert rec >= 0.9, (name, rec)


def test_save_load_roundtrip(tmp_path, sift_small):
    ds = sift_small
    sess = open_index(ds.X, index="ivf", method="DADE",
                      index_params={"n_list": 32})
    before = sess.search(ds.Q[:5], K, nprobe=8)
    path = os.path.join(tmp_path, "session.bin")
    sess.save(path)
    loaded = SearchSession.load(path)
    after = loaded.search(ds.Q[:5], K, nprobe=8)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_allclose(before.dists, after.dists, rtol=1e-6)
    # loaded session still supports dynamic adds
    loaded.add(ds.Q[:3])
    assert loaded.n == ds.n + 3


def test_jax_backend_rejects_hnsw(sift_small):
    """HNSW graph walks stay host-side; flat and ivf are device-served
    (the device IVF probe path is covered in test_stream_engine)."""
    ds = sift_small
    with pytest.raises(ValueError, match="flat"):
        open_index(ds.X[:256], index="hnsw", method="PDScanning+",
                   backend="jax", index_params={"m": 4, "ef_construction": 8})


def test_search_stats_aggregate(sift_small):
    """Facade stats cover the whole batch and show real pruning."""
    ds = sift_small
    res = open_index(ds.X, index="flat", method="PDScanning+").search(ds.Q[:6], K)
    assert res.stats.n_dco == 6 * ds.n
    assert 0.0 < res.stats.pruning_ratio < 1.0
    assert res.qps > 0 and res.nq == 6 and res.k == K
