"""Crash-safe delta WAL (DESIGN.md §7, api.persistence.DeltaWAL).

The contract under test: an insert acknowledged by ``add()`` is on disk
before ``add()`` returns, so ANY crash after the acknowledgement loses
nothing; a crash *during* the write tears only a frame whose insert was
never acknowledged, and the loader drops it with a warning instead of
crashing.  Replay is idempotent (frames carry the corpus size they were
logged against), and ``save()`` clears the log because a fresh snapshot
supersedes every frame.
"""
import os
import warnings

import numpy as np
import pytest

from repro.api import (DeltaWAL, IndexLoadError, SchedulePolicy,
                       SearchSession, open_index)
from repro.api.persistence import wal_path
from repro.testing import SimulatedCrash, faults


def _data(n=600, d=16, seed=3):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(64, d)).astype(np.float32),
            rng.normal(size=(6, d)).astype(np.float32))


def _snap(tmp_path):
    return str(tmp_path / "idx.bin")


# ------------------------------------------------------------ happy path ----
def test_save_arms_wal_and_reload_replays(tmp_path):
    X, extra, Q = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)            # build + save: WAL armed
    assert sess.wal is not None and os.path.exists(wal_path(p))
    sess.add(extra[:20])
    sess.add(extra[20:40])
    re = SearchSession.load(p)
    assert re.n == sess.n == X.shape[0] + 40
    a, b = sess.search(Q, 5), re.search(Q, 5)
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.dists, b.dists)


def test_kill_after_add_loses_no_acknowledged_insert(tmp_path):
    """The acceptance scenario: snapshot, acknowledged adds, simulated kill
    (just drop the session object — the WAL write already happened inside
    add()), reload; recall vs a brute-force oracle over the FULL corpus
    must be exactly 1.0."""
    X, extra, Q = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra)                          # acknowledged
    del sess                                 # "kill -9": no save() ran
    re = SearchSession.load(p)
    full = np.concatenate([X, extra])
    assert re.n == full.shape[0]
    res = re.search(Q, 10)
    d2 = ((Q[:, None] - full[None]) ** 2).sum(-1)
    oracle = np.argsort(d2, 1)[:, :10]
    recall = np.mean([len(set(res.ids[i]) & set(oracle[i])) / 10
                      for i in range(Q.shape[0])])
    assert recall == 1.0


def test_replay_is_idempotent(tmp_path):
    """Double replay == single replay: loading twice (each load replays)
    and replaying the armed log against an already-caught-up session both
    apply nothing new."""
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra[:16])
    one = SearchSession.load(p)
    two = SearchSession.load(p)
    assert one.n == two.n == X.shape[0] + 16
    assert one.wal.replay(one) == 0          # explicit second replay: no-op
    assert one.n == X.shape[0] + 16


def test_save_clears_the_log(tmp_path):
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra[:16])
    assert os.path.getsize(wal_path(p)) > 0
    sess.save(p)                             # snapshot absorbs the deltas
    assert os.path.getsize(wal_path(p)) == 0
    assert SearchSession.load(p).n == X.shape[0] + 16


# ------------------------------------------------------------ torn writes ----
def test_torn_write_never_acknowledges_and_recovers(tmp_path):
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra[:10])                     # good frame before the tear
    with faults.inject(torn_frame_keep=0.5):
        with pytest.raises(SimulatedCrash):
            sess.add(extra[10:20])           # never acknowledged
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        re = SearchSession.load(p)
    assert any("torn" in str(x.message) for x in w)
    assert re.n == X.shape[0] + 10           # good frame kept, tear dropped


@pytest.mark.parametrize("keep", [0.0, 0.1, 0.9])
def test_torn_tail_any_length_is_dropped(tmp_path, keep):
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    with faults.inject(torn_frame_keep=keep):
        with pytest.raises(SimulatedCrash):
            sess.add(extra[:8])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        re = SearchSession.load(p)
    assert re.n == X.shape[0]


def test_recovery_truncates_so_later_appends_survive(tmp_path):
    """A torn tail must not poison the log: after a recovering load the
    next append lands on a frame boundary and survives the next load."""
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    with faults.inject(torn_frame_keep=0.4):
        with pytest.raises(SimulatedCrash):
            sess.add(extra[:8])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        re = SearchSession.load(p)           # truncates the torn tail
    re.add(extra[8:12])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        again = SearchSession.load(p)
        assert not [x for x in w if "torn" in str(x.message)]
    assert again.n == X.shape[0] + 4


def test_corrupt_middle_frame_stops_replay_at_it(tmp_path):
    """Bit-rot in an earlier frame drops it AND everything after (order
    matters for n_before bookkeeping) — with a warning, never a crash."""
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra[:8])
    sess.add(extra[8:16])
    wp = wal_path(p)
    raw = bytearray(open(wp, "rb").read())
    raw[len(raw) // 2] ^= 0xFF               # flip a bit mid-file
    open(wp, "wb").write(bytes(raw))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        re = SearchSession.load(p)
    assert any("CRC" in str(x.message) or "torn" in str(x.message) for x in w)
    assert X.shape[0] <= re.n < X.shape[0] + 16


# --------------------------------------------------------------- loading ----
def test_load_errors_are_typed_and_name_the_path(tmp_path):
    missing = str(tmp_path / "nope.bin")
    with pytest.raises(IndexLoadError, match="does not exist") as ei:
        SearchSession.load(missing)
    assert ei.value.path == missing
    bad = tmp_path / "bad.bin"
    bad.write_bytes(b"\x00 this is not a snapshot")
    with pytest.raises(IndexLoadError, match="integrity trailer"):
        SearchSession.load(str(bad))        # foreign file: no SNAP trailer
    notdict = tmp_path / "notdict.bin"
    import pickle
    notdict.write_bytes(_with_trailer(pickle.dumps([1, 2, 3])))
    with pytest.raises(IndexLoadError, match="not a session snapshot"):
        SearchSession.load(str(notdict))


def _with_trailer(body: bytes) -> bytes:
    """Append a VALID integrity trailer, as save_session would."""
    import struct
    import zlib
    return body + b"SNAP" + struct.pack("<QI", len(body), zlib.crc32(body))


# ----------------------------------------------------- snapshot integrity ----
def test_snapshot_bitflip_is_detected_before_unpickling(tmp_path):
    """A flipped bit anywhere in the pickle payload must fail the crc32
    check with a typed error — never reach ``pickle.loads``."""
    X, _, _ = _data()
    p = _snap(tmp_path)
    open_index(X, path=p)
    raw = bytearray(open(p, "rb").read())
    raw[len(raw) // 2] ^= 0x01               # single bit, mid-payload
    open(p, "wb").write(bytes(raw))
    with pytest.raises(IndexLoadError, match="checksum mismatch") as ei:
        SearchSession.load(p)
    assert ei.value.path == p


def test_snapshot_truncation_is_detected(tmp_path):
    """Losing the tail (trailer gone or payload short) is a typed load
    error, whichever byte the cut lands on."""
    X, _, _ = _data()
    p = _snap(tmp_path)
    open_index(X, path=p)
    raw = open(p, "rb").read()
    for keep in (len(raw) - 1, len(raw) - 8, len(raw) // 2, 3):
        open(p, "wb").write(raw[:keep])
        with pytest.raises(IndexLoadError,
                           match="integrity trailer|checksum mismatch"):
            SearchSession.load(p)


def test_trailer_corruption_is_detected(tmp_path):
    """Bit-rot in the trailer itself (stored crc) also fails closed."""
    X, _, _ = _data()
    p = _snap(tmp_path)
    open_index(X, path=p)
    raw = bytearray(open(p, "rb").read())
    raw[-1] ^= 0xFF                          # stored crc32 byte
    open(p, "wb").write(bytes(raw))
    with pytest.raises(IndexLoadError, match="checksum mismatch"):
        SearchSession.load(p)


# ------------------------------------------------------- non-finite rows ----
def test_add_rejects_non_finite_rows(tmp_path):
    """add() refuses NaN/Inf rows BEFORE logging them, so poison never
    reaches the WAL through the public path."""
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    poison = extra[:4].copy()
    poison[1, 0] = np.nan
    poison[3, 2] = np.inf
    with pytest.raises(ValueError, match="NaN/Inf"):
        sess.add(poison)
    assert sess.n == X.shape[0]              # nothing inserted
    re = SearchSession.load(p)               # nothing logged either
    assert re.n == X.shape[0]


def test_replay_skips_non_finite_frames_with_warning(tmp_path):
    """Defense in depth: a poison frame already ON DISK (written by an
    older build, or bit-rot that kept the CRC valid) is skipped at replay
    with a warning, and clean frames after it still apply."""
    X, extra, Q = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra[:8])                      # clean frame, n_before=600
    poison = extra[8:12].copy()
    poison[0, 0] = np.nan
    sess.wal.append(poison, sess.n)          # bypass add()'s validation
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        re = SearchSession.load(p)
    assert any("non-finite" in str(x.message) for x in w)
    assert re.n == X.shape[0] + 8            # clean frame applied, poison not
    clean = np.concatenate([X, extra[:8]])
    oracle = np.argsort(((clean[None] - Q[:, None]) ** 2).sum(-1), 1)[:, :5]
    got = re.search(Q, 5).ids
    assert np.array_equal(np.sort(got, 1), np.sort(oracle, 1))


def test_open_index_path_roundtrip_and_ivf(tmp_path):
    """open_index(path=...) loads snapshot+WAL; works for ivf too (replay
    runs the real insert path, so partition lists stay consistent)."""
    X, extra, Q = _data()
    p = _snap(tmp_path)
    sess = open_index(X, index="ivf", path=p,
                      schedule=SchedulePolicy(d1=16))
    sess.add(extra[:12])
    re = open_index(path=p)
    assert re.index_kind == "ivf" and re.n == X.shape[0] + 12
    assert np.array_equal(sess.search(Q, 5, nprobe=64).ids,
                          re.search(Q, 5, nprobe=64).ids)
    with pytest.raises(ValueError, match="pass vectors X"):
        open_index()


def test_wal_without_snapshot_is_inert(tmp_path):
    """Sessions never tied to a path keep the pre-PR behavior: no log."""
    X, extra, _ = _data()
    sess = open_index(X)
    assert sess.wal is None
    sess.add(extra[:4])                      # no file side effects
    assert not os.listdir(tmp_path)


# ------------------------------------------------- atomic save crash points --
def test_crash_mid_save_keeps_old_snapshot_and_wal(tmp_path):
    """Kill the process between the tmp write and the atomic rename (the
    worst point): the previous snapshot AND its delta frames must reload
    intact — the failed save loses nothing."""
    X, extra, Q = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p)
    sess.add(extra[:20])                     # acknowledged, in the WAL
    with faults.inject(crash_save=0):
        with pytest.raises(SimulatedCrash, match="rename never happened"):
            sess.save(p)
    re = SearchSession.load(p)               # old snapshot + WAL replay
    assert re.n == X.shape[0] + 20
    full = np.concatenate([X, extra[:20]])
    oracle = np.argsort(((Q[:, None] - full[None]) ** 2).sum(-1), 1)[:, :5]
    assert np.array_equal(np.sort(re.search(Q, 5).ids, 1),
                          np.sort(oracle, 1))
    # the tier heals: the next save lands atomically and absorbs the log
    sess.save(p)
    assert os.path.getsize(wal_path(p)) == 0
    assert SearchSession.load(p).n == X.shape[0] + 20


def test_crash_mid_save_before_any_wal_is_clean_slate(tmp_path):
    """Crash on the very first save: no snapshot exists yet, and the load
    error is the typed missing-file one, not a torn hybrid."""
    X, _, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X)
    with faults.inject(crash_save=0):
        with pytest.raises(SimulatedCrash):
            sess.save(p)
    assert not os.path.exists(p)             # only the tmp file remains
    with pytest.raises(IndexLoadError, match="does not exist"):
        SearchSession.load(p)


# ------------------------------------------------------- segment rotation ----
def test_wal_rotation_splits_segments_and_replays_in_order(tmp_path):
    """With ``wal_max_bytes`` set, appends past the cap open numbered
    segments; replay walks them in order and reconstructs the corpus."""
    X, extra, Q = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p, schedule=SchedulePolicy(wal_max_bytes=1))
    for i in range(3):                       # cap=1 byte: every add rotates
        sess.add(extra[10 * i:10 * (i + 1)])
    segs = sess.wal._segments()
    assert segs == [wal_path(p), f"{wal_path(p)}.0001", f"{wal_path(p)}.0002"]
    assert sess.wal.total_bytes() == sum(os.path.getsize(s) for s in segs)
    re = SearchSession.load(p)
    assert re.n == X.shape[0] + 30
    assert np.array_equal(sess.search(Q, 5).ids, re.search(Q, 5).ids)


def test_wal_rotation_clear_removes_every_segment(tmp_path):
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p, schedule=SchedulePolicy(wal_max_bytes=1))
    for i in range(3):
        sess.add(extra[8 * i:8 * (i + 1)])
    assert len(sess.wal._segments()) == 3
    sess.save(p)                             # snapshot absorbs + clears
    assert sess.wal._segments() == [wal_path(p)]
    assert os.path.getsize(wal_path(p)) == 0
    assert not [f for f in os.listdir(tmp_path)
                if f.startswith("idx.bin.wal.")]
    assert SearchSession.load(p).n == X.shape[0] + 24


def test_wal_rotation_torn_tail_truncates_only_last_segment(tmp_path):
    """A torn frame in the newest segment drops only that unacknowledged
    tail; every rotated-out segment replays whole, and the post-recovery
    append survives."""
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p, schedule=SchedulePolicy(wal_max_bytes=1))
    sess.add(extra[:8])
    sess.add(extra[8:16])
    with faults.inject(torn_frame_keep=0.5):
        with pytest.raises(SimulatedCrash):
            sess.add(extra[16:24])           # tears segment .0002
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        re = SearchSession.load(p)           # truncates the torn segment
    assert any("torn" in str(x.message) for x in w)
    assert re.n == X.shape[0] + 16
    re.add(extra[16:20])
    assert SearchSession.load(p).n == X.shape[0] + 20


def test_wal_bytes_surfaces_in_serving_health(tmp_path):
    X, extra, _ = _data()
    p = _snap(tmp_path)
    sess = open_index(X, path=p, schedule=SchedulePolicy(wal_max_bytes=1))
    svc = sess.serve(slots=2, k=5)
    svc.add(extra[:8])
    svc.add(extra[8:16])
    h = svc.health()
    assert h["wal_bytes"] == sess.wal.total_bytes() > 0


def test_frames_roundtrip_unit(tmp_path):
    """DeltaWAL alone: frames come back in order with exact payloads."""
    wal = DeltaWAL(tmp_path / "unit.wal")
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    b = -np.ones((2, 4), np.float32)
    wal.append(a, 100)
    wal.append(b, 103)
    frames = wal.frames()
    assert [f[0] for f in frames] == [100, 103]
    assert np.array_equal(frames[0][1], a)
    assert np.array_equal(frames[1][1], b)
    wal.clear()
    assert wal.frames() == []
