"""Per-architecture smoke tests (brief deliverable f): reduced same-family
config, one forward/train step on CPU, asserts output shapes + no NaNs.
Also prefill->decode consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch, smoke_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm" and cfg.prefix_len:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    api = build_model(cfg, remat="none")
    params = api.init(KEY)
    loss, metrics = jax.jit(api.loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_full_config_is_exact(arch):
    """The FULL config (exercised via dry-run only) matches the assignment."""
    cfg = get_arch(arch)
    spec = {
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "deepseek-v2-236b": (60, 5120, 128, 128, None, 102400),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }[arch]
    L, d, h, kv, ff, vocab = spec
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == vocab
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    if arch.startswith("deepseek"):
        assert cfg.mla is not None and cfg.mla.kv_lora == 512
        assert cfg.moe.n_experts == (160 if "v2" in arch else 256)
        assert cfg.moe.top_k == (6 if "v2" in arch else 8)
    if arch == "jamba-v0.1-52b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
        assert cfg.attn_every == 8
    if arch == "mamba2-130m":
        assert cfg.ssm.d_state == 128


@pytest.mark.parametrize("arch", ["qwen3-4b", "olmo-1b", "deepseek-v2-236b",
                                  "mamba2-130m", "jamba-v0.1-52b",
                                  "paligemma-3b", "seamless-m4t-large-v2"])
def test_prefill_decode_consistency(arch):
    """Prefill over S tokens == feeding the same tokens through decode_step
    one at a time (the correctness backbone for KV/SSM caches)."""
    cfg = smoke_config(arch)
    api = build_model(cfg, remat="none")
    params = api.init(KEY)
    B, S, MAX = 2, 12, 24
    batch = _batch(cfg, B, S)
    logits_pre, _ = jax.jit(api.prefill)(params, batch)

    cache = api.init_cache(B, MAX)
    decode = jax.jit(api.decode_step)
    toks = np.asarray(batch["tokens"])
    logits = None
    for t in range(S):
        logits, cache = decode(params, cache, jnp.asarray(toks[:, t]), t + 1)
    # VLM prefill prepends patches that token-decode can't replay; skip value
    # check there but still verify shapes/finiteness.
    assert logits.shape == logits_pre.shape
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.family not in ("vlm", "encdec"):
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(logits_pre, np.float32),
                                   rtol=0.15, atol=0.2)
        # greedy agreement on the real vocab
        a = np.argmax(np.asarray(logits)[:, :cfg.vocab], -1)
        b = np.argmax(np.asarray(logits_pre)[:, :cfg.vocab], -1)
        assert (a == b).mean() >= 0.5
