"""End-to-end behaviour tests for the paper's system.

1. Retrieval stack end-to-end: synthetic corpus -> IVF -> DCO query ->
   recall + pruning, for a baseline and a SOTA method; the SOTA method
   must prune strictly more than FDScanning at equal recall.
2. Paper-claims sanity: the dimensionality-sensitivity direction — pruning
   ratio on high-D data exceeds pruning on low-D data for PCA methods.
3. LM stack end-to-end: train a reduced model for a few steps through the
   resumable driver, then serve it through the engine.
"""
import jax
import numpy as np
import pytest

from repro.api import SearchSession
from repro.core.engine import QueryBatch, ScanStats, make_schedule
from repro.core.methods import make_method
from repro.search.ivf import IVFIndex
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k

K = 10


def test_retrieval_end_to_end(sift_small):
    ds = sift_small
    idx = IVFIndex(n_list=32).build(ds.X)
    gt, _ = ds.ground_truth(K)
    results = {}
    for name in ("FDScanning", "DDCres"):
        m = make_method(name).fit(ds.X)
        res = SearchSession(m, "ivf", idx).search(ds.Q[:10], K, nprobe=16)
        results[name] = (recall_at_k(res.ids, gt[:10]), res.stats)
    rec_fd, st_fd = results["FDScanning"]
    rec_res, st_res = results["DDCres"]
    assert abs(rec_fd - rec_res) < 0.05          # recall preserved (paper)
    assert st_res.pruning_ratio > st_fd.pruning_ratio + 0.2


def test_dimensionality_sensitivity_direction():
    """Paper finding (1): pruning grows with dimensionality for PCA methods."""
    lo = load_dataset("deep", scale=0.02)        # D=96
    hi = load_dataset("gist", scale=0.1)         # D=960
    ratios = {}
    for ds in (lo, hi):
        m = make_method("DDCres").fit(ds.X)
        stats = ScanStats()
        batch = QueryBatch.create(m, ds.Q[:6], make_schedule(ds.dim), stats)
        from repro.core.engine import scan_topk
        for qi in range(6):
            scan_topk(m, batch, qi, np.arange(ds.n), K)
        ratios[ds.name] = stats.pruning_ratio
    assert ratios["gist"] > ratios["deep"], ratios


def test_lm_train_then_serve(tmp_path):
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serving.engine import Request, ServingEngine
    from repro.train.fault import run_resumable
    from repro.train.train_step import init_state, make_train_step
    import jax.numpy as jnp

    cfg = smoke_config("qwen3-4b")
    api = build_model(cfg, remat="none")
    state = init_state(api, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(api))

    def batch_fn(s):
        rng = np.random.default_rng(s % 3)       # small cycling corpus
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                      jnp.int32)}

    state, last = run_resumable(step, state, batch_fn, steps=8,
                                ckpt_dir=str(tmp_path), ckpt_every=4)
    assert last == 7
    eng = ServingEngine(api, slots=2, max_len=32)
    out = eng.run(state.params,
                  [Request(rid=0, prompt=np.array([1, 2, 3]), max_new=4)])
    assert len(out[0]) == 4
