"""Fault-tolerant replicated serving (DESIGN.md §10, serving.replica).

The tier's contracts:

1. **Healthy tiers are transparent**: a replicate-mode service answers
   exactly like a single session; a shard-mode service's merged global
   top-k is bit-identical to one session over the whole corpus.

2. **Faults degrade, never lie**: a dead replica is retried around
   (replicate) or answered past (shard) — degraded answers carry
   ``coverage < 1``, a withdrawn certificate, and a ``degraded`` flag,
   and are *bit-identical to the brute-force top-k over the surviving
   shards' union* (the spatial analogue of PR 7's anytime prefix oracle).

3. **The lifecycle never leaks**: every acknowledged ticket resolves,
   ``submitted == completed + shed + timeouts + failures + pending``
   holds through kills and revivals, and the service outlives the batch
   that had no replica left.

4. **Routing heals**: ejection after consecutive failures, half-open
   probes on real traffic, re-admission after clean probes — all on the
   PR 9 breaker core, all visible in ``health()``.

5. **Chaos is replay-exact**: with an injected timer and jitter RNG, two
   runs produce identical routing, hedging decisions, and timelines.
"""
import numpy as np
import pytest

from repro.api import open_index
from repro.core.engine import (EXTRA_COVERAGE, EXTRA_DEGRADED, EXTRA_HEDGED,
                               EXTRA_REPLICA, EXTRA_UNCERTIFIED_MASK)
from repro.serving import (ReplicaDispatchError, ReplicaPolicy,
                           ReplicatedService, open_replicated)
from repro.testing import FaultPlan, faults


def _data(n=900, d=24, nq=12, seed=11):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(nq, d)).astype(np.float32))


def _tier(X, **kw):
    kw.setdefault("replicas", 3)
    kw.setdefault("k", 8)
    kw.setdefault("slots", 4)
    return open_replicated(X, **kw)


def _submit_all(svc, Q, t0=0.0):
    for j, q in enumerate(Q):
        svc.submit(q, now=t0 + 1e-4 * j)


def _by_rid(reqs):
    return sorted([r for r in reqs if r.status == "done"],
                  key=lambda r: r.rid)


def _oracle(X, Q, k):
    d = ((Q[:, None, :] - X[None, :, :]) ** 2).sum(-1)
    ids = np.argsort(d, axis=1, kind="stable")[:, :k]
    return ids


def _acct(svc):
    h = svc.health()
    return h["submitted"] == (h["completed"] + h["shed"] + h["timeouts"]
                              + h["failures"] + svc.pending)


# ------------------------------------------------------------ transparency --
@pytest.mark.parametrize("mode", ["replicate", "shard"])
def test_healthy_tier_matches_single_session(mode):
    X, Q = _data()
    svc = _tier(X, mode=mode)
    _submit_all(svc, Q)
    done = _by_rid(svc.drain(now=1.0))
    assert len(done) == len(Q)
    ref = open_index(X, method="DADE").search(Q, 8)
    got = np.stack([r.ids for r in done])
    assert np.array_equal(got, ref.ids)
    for r in done:
        assert r.certified is True and r.coverage == 1.0
        assert r.stats[EXTRA_DEGRADED] == 0.0
    assert _acct(svc)


def test_replicate_round_robins_over_replicas():
    X, Q = _data(nq=12)
    svc = _tier(X, mode="replicate", slots=2,
                replica_policy=ReplicaPolicy(hedge=False))
    _submit_all(svc, Q)
    svc.drain(now=1.0)
    served = [rs.served for rs in svc.replicas]
    assert sum(served) == 6 and max(served) - min(served) <= 1


# ----------------------------------------------------------- retry/backoff --
def test_dead_replica_is_retried_on_another():
    X, Q = _data(nq=4)
    svc = _tier(X, mode="replicate", slots=4)
    with faults.inject(dead_replica=0):
        _submit_all(svc, Q)
        done = svc.drain(now=1.0)
    assert all(r.status == "done" for r in done)
    h = svc.health()
    assert h["failures"] == 0 and h["retries"] >= 1
    assert svc.replicas[0].failures >= 1
    assert _acct(svc)


def test_backoff_is_capped_exponential_and_deterministic():
    X, _ = _data()
    pol = ReplicaPolicy(backoff_base_s=0.01, backoff_cap_s=0.03,
                        jitter=0.5, seed=3)
    a = _tier(X, mode="replicate", replica_policy=pol)
    b = _tier(X, mode="replicate", replica_policy=pol)
    da = [a._backoff(i) for i in range(1, 6)]
    db = [b._backoff(i) for i in range(1, 6)]
    assert da == db                       # same seed -> same jitter stream
    for i, d in enumerate(da, start=1):
        base = min(0.03, 0.01 * 2 ** (i - 1))
        assert base <= d <= base * 1.5
    assert max(da) <= 0.03 * 1.5          # cap holds jitter included


def _kill_sessions(svc):
    """Break every replica's backend (the connection-level failure the
    tier must survive).  Returns the original bound methods for healing."""
    saved = [rs.session.search for rs in svc.replicas]
    for rs in svc.replicas:
        def _down(*a, _i=rs.idx, **k):
            raise RuntimeError(f"replica {_i} backend down")
        rs.session.search = _down
    return saved


def _heal_sessions(svc, saved):
    for rs, fn in zip(svc.replicas, saved):
        rs.session.search = fn


def test_all_replicas_down_fails_batch_not_service():
    X, Q = _data(nq=6)
    svc = _tier(X, mode="replicate", slots=3,
                replica_policy=ReplicaPolicy(max_retries=2, eject_after=1))
    saved = _kill_sessions(svc)
    _submit_all(svc, Q[:3])
    out = svc.drain(now=1.0)
    assert all(r.status == "failed" for r in out)
    assert all("replica" in r.error for r in out)
    assert _acct(svc)
    # the service survives: heal the replicas and serve again
    _heal_sessions(svc, saved)
    _submit_all(svc, Q[3:], t0=2.0)
    out2 = svc.drain(now=3.0)
    assert all(r.status == "done" for r in out2)
    assert _acct(svc)


def test_dispatch_error_carries_wall():
    err = ReplicaDispatchError("boom", wall_s=0.25)
    assert err.wall_s == 0.25


# ----------------------------------------------- ejection and re-admission --
def test_ejection_then_half_open_probe_readmits():
    X, Q = _data(nq=24)
    pol = ReplicaPolicy(eject_after=2, probe_after=2, promote_after=2,
                        max_retries=1, hedge=False)
    svc = _tier(X, mode="replicate", slots=2, replica_policy=pol)
    plan = faults.install(FaultPlan(dead_replica=1))
    try:
        _submit_all(svc, Q[:12])
        svc.drain(now=1.0)
    finally:
        faults.install(plan)
    rs = svc.replicas[1]
    # ejected; a probe window may already be open (probes fail while the
    # fault is live, bouncing half_open -> open -> half_open)
    assert rs.state in ("open", "half_open")
    assert any(t["to"] == "open" and "ejected" in t["reason"]
               for t in rs.breaker.transitions)
    # revived: probe window opens after probe_after quiet rounds, then
    # promote_after successful probes re-admit
    _submit_all(svc, Q[12:], t0=2.0)
    svc.drain(now=3.0)
    assert rs.state == "closed"
    reasons = [t["reason"] for t in rs.breaker.transitions]
    assert any("probe window" in r for r in reasons)
    assert any("re-admitted" in r for r in reasons)
    assert rs.probes >= pol.promote_after
    assert svc.health()["failures"] == 0 and _acct(svc)


# ------------------------------------------------------------------ hedging --
def _slow_timer(slow_idx, slow_s=0.2, fast_s=0.01):
    return lambda idx, wall: slow_s if idx == slow_idx else fast_s


def test_hedge_fires_and_wins_on_slow_replica():
    X, Q = _data(nq=16)
    pol = ReplicaPolicy(hedge=True, hedge_factor=2.0, hedge_min_delay_s=0.02,
                        jitter=0.0)
    svc = _tier(X, mode="replicate", slots=2, replica_policy=pol,
                timer=_slow_timer(0))
    _submit_all(svc, Q)
    done = _by_rid(svc.drain(now=1.0))
    h = svc.health()
    # replica 0's p99 EWMA converges near 0.2s; once its wall (0.2) exceeds
    # 2x the healthy floor it would never hedge against itself — but the
    # round-robin makes healthy replicas the primary for 2/3 of batches, so
    # hedges fire exactly when 0 is primary and its wall >> the fleet's
    assert h["hedges"] >= 1
    assert h["hedge_wins"] >= 1
    hedged = [r for r in done if r.stats[EXTRA_HEDGED] == 1.0]
    assert hedged
    for r in hedged:
        assert r.stats[EXTRA_REPLICA] != 0.0    # a healthy replica won
        assert r.service_s < 0.2                # beat the straggler's wall
    assert _acct(svc)


def test_hedged_dispatch_is_replay_exact():
    """Injected clock (timer) + seeded jitter RNG => two runs produce
    identical routing, hedge decisions, and per-ticket timelines."""
    X, Q = _data(nq=16)

    def run():
        pol = ReplicaPolicy(hedge=True, hedge_factor=2.0,
                            hedge_min_delay_s=0.02, seed=5)
        svc = _tier(X, mode="replicate", slots=2, replica_policy=pol,
                    timer=_slow_timer(1))
        _submit_all(svc, Q)
        done = _by_rid(svc.drain(now=1.0))
        h = svc.health()
        return ([(r.rid, r.t_done, r.service_s, r.stats[EXTRA_REPLICA],
                  r.stats[EXTRA_HEDGED]) for r in done],
                (h["hedges"], h["hedge_wins"], h["hedge_losses"],
                 h["retries"]))
    t1, c1 = run()
    t2, c2 = run()
    assert t1 == t2 and c1 == c2


def test_no_hedge_when_primary_is_fast():
    X, Q = _data(nq=8)
    svc = _tier(X, mode="replicate", slots=2,
                replica_policy=ReplicaPolicy(hedge=True, hedge_factor=3.0),
                timer=lambda idx, wall: 0.01)
    _submit_all(svc, Q)
    svc.drain(now=1.0)
    assert svc.health()["hedges"] == 0


# ------------------------------------------- shard loss: spatial coverage ---
def test_shard_loss_matches_surviving_union_oracle():
    """Degraded answers are bit-identical to brute force over the union of
    surviving shards, with coverage < 1 and certificates withdrawn."""
    X, Q = _data(n=903)                   # not divisible by 3: uneven shards
    svc = _tier(X, mode="shard", replicas=3)
    dead = 1
    lo = svc.replicas[dead].id_offset
    hi = lo + svc.replicas[dead].rows
    surviving = np.concatenate([X[:lo], X[hi:]])
    surviving_ids = np.concatenate([np.arange(lo), np.arange(hi, X.shape[0])])
    with faults.inject(dead_replica=dead):
        _submit_all(svc, Q)
        done = _by_rid(svc.drain(now=1.0))
    assert len(done) == len(Q)
    ref = surviving_ids[_oracle(surviving, Q, 8)]
    got = np.stack([r.ids for r in done])
    assert np.array_equal(got, ref)
    want_cov = surviving.shape[0] / X.shape[0]
    for r in done:
        assert r.certified is False
        assert r.coverage == pytest.approx(want_cov)
        assert r.stats[EXTRA_DEGRADED] == 1.0
        assert r.stats[EXTRA_REPLICA] == -1.0
    h = svc.health()
    assert h["degraded"] == len(Q) and h["failures"] == 0
    assert _acct(svc)


def test_shard_revival_restores_full_coverage():
    X, Q = _data(nq=18)
    pol = ReplicaPolicy(eject_after=1, probe_after=1, promote_after=1,
                        max_retries=0)
    svc = _tier(X, mode="shard", replicas=3, slots=3, replica_policy=pol)
    plan = faults.install(FaultPlan(dead_replica=2))
    try:
        _submit_all(svc, Q[:9])
        degraded = _by_rid(svc.drain(now=1.0))
    finally:
        faults.install(plan)
    assert all(r.coverage < 1.0 and not r.certified for r in degraded)
    _submit_all(svc, Q[9:], t0=2.0)
    healed = _by_rid(svc.drain(now=3.0))
    # probes re-admit the shard, after which answers are full-coverage again
    assert svc.replicas[2].state == "closed"
    assert any(r.coverage == 1.0 and r.certified for r in healed)
    ref = open_index(X, method="DADE").search(Q[9:], 8)
    full = [r for r in healed if r.coverage == 1.0]
    assert np.array_equal(np.stack([r.ids for r in full]),
                          ref.ids[-len(full):])
    assert _acct(svc)


def test_all_shards_down_fails_batch():
    X, Q = _data(nq=3)
    svc = _tier(X, mode="shard", replicas=2, slots=3,
                replica_policy=ReplicaPolicy(max_retries=0, eject_after=1))
    _kill_sessions(svc)
    _submit_all(svc, Q)
    out = svc.drain(now=1.0)
    assert all(r.status == "failed" for r in out)
    assert _acct(svc)


# ------------------------------------------------------------------- writes --
def test_replicate_add_fans_out_and_serves_new_rows():
    X, Q = _data()
    svc = _tier(X, mode="replicate")
    Xn = X[:1] + 1e-3
    svc.add(Xn)
    assert all(rs.session.n == X.shape[0] + 1 for rs in svc.replicas)
    assert svc.health()["rows_inserted"] == 1


def test_shard_add_appends_to_tail_shard_with_contiguous_ids():
    X, Q = _data(n=900)
    svc = _tier(X, mode="shard", replicas=3)
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(5, X.shape[1])).astype(np.float32)
    svc.add(Xn)
    last = max(svc.replicas, key=lambda rs: rs.id_offset)
    assert last.rows == 300 + 5
    Xall = np.concatenate([X, Xn])
    _submit_all(svc, Q)
    done = _by_rid(svc.drain(now=1.0))
    ref = _oracle(Xall, Q, 8)
    assert np.array_equal(np.stack([r.ids for r in done]), ref)
    assert all(r.n_visible == 905 for r in done)


# ------------------------------------------------------------- validation ---
def test_tier_rejects_bad_construction():
    X, _ = _data(n=64)
    with pytest.raises(ValueError, match="mode"):
        open_replicated(X, mode="nope")
    with pytest.raises(ValueError, match="replicas"):
        open_replicated(X, replicas=0)
    with pytest.raises(ValueError, match="non-empty"):
        open_replicated(X[:2], replicas=3, mode="shard")
    s1 = open_index(X, method="DADE")
    s2 = open_index(X[:, :12], method="DADE")
    with pytest.raises(ValueError, match="disagree on D"):
        ReplicatedService([s1, s2])
    with pytest.raises(ValueError, match="at least one"):
        ReplicatedService([])


def test_accounting_exact_under_churn():
    """Kill, shed, timeout, revive — the invariant never drifts."""
    X, Q = _data(nq=30)
    svc = _tier(X, mode="replicate", slots=2, max_queue=4,
                admission="shed_oldest", deadline_s=0.5,
                replica_policy=ReplicaPolicy(max_retries=1, eject_after=1))
    plan = faults.install(FaultPlan(dead_replica=0, fail_replica_after=4))
    try:
        t = 0.0
        for j, q in enumerate(Q):
            svc.submit(q, now=t)
            if j % 3 == 2:
                svc.step(now=t)
            t += 0.05
        svc.drain(now=t)
    finally:
        faults.install(plan)
    assert svc.pending == 0
    assert _acct(svc)
