"""Streaming engine (core.stream_engine) coverage: parity vs the two-stage
engine and the host scan across all decision rules, kernel-vs-jnp path
identity, ragged query batches, ragged corpus blocks, k > capacity, and the
device-side IVF probe path."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SchedulePolicy, open_index
from repro.core.engine import make_schedule
from repro.core.jax_engine import (DcoEngineConfig, build_device_state,
                                   two_stage_topk)
from repro.core.methods import make_method
from repro.core.stream_engine import stream_topk
from repro.vecdata.synthetic import recall_at_k

K = 10

#: facade method -> engine decision rule it exercises (all six dco_scan
#: rules plus DDCopq's PQ rule, which only the streaming engine serves)
RULES = {"FDScanning": "fdscan", "PDScanning+": "lb",
         "ADSampling": "adsampling", "DADE": "dade",
         "DDCres": "ddcres", "DDCpca": "ratio", "DDCopq": "opq"}


def _fitted(ds, name):
    m = make_method(name).fit(ds.X)
    if m.needs_training:
        rng = np.random.default_rng(7)
        m.train(ds.X[rng.choice(ds.n, 24)], K, make_schedule(ds.dim))
    return m


def _policy(**kw):
    base = dict(d1=48, query_chunk=8, capacity=512, row_block=512,
                block_capacity=128)
    base.update(kw)
    return SchedulePolicy(**base)


@pytest.mark.parametrize("kind", ["lb", "fdscan"])
def test_stream_bit_identical_to_two_stage_on_exact_rules(kind, sift_small):
    """Acceptance: on exact rules the streaming engine returns bit-identical
    top-k (ids AND squared distances) to the two-stage engine."""
    ds = sift_small
    m = make_method("PDScanning+").fit(ds.X)
    cfg = DcoEngineConfig(kind=kind, d1=48, k=K, capacity=512, query_chunk=8,
                          row_block=512, block_capacity=128, use_kernel=False)
    st = build_device_state(m, cfg.d1)
    Q = jnp.asarray(ds.Q[:8]) @ jnp.asarray(m.state["pca"]["W"])
    d0, i0, _ = two_stage_topk(st, Q[:, :cfg.d1], Q[:, cfg.d1:], cfg)
    d1_, i1, s1, p1, dm1, _ = stream_topk(st, Q[:, :cfg.d1], Q[:, cfg.d1:], cfg)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1_))
    assert (np.asarray(s1) > 0).all() and (np.asarray(p1) >= np.asarray(s1)).all()


def test_stream_all_rules_facade_parity(sift_small):
    """Every decision rule through the facade: exact rules match the host
    backend exactly; estimator rules hold the same recall bar the host path
    is tested at elsewhere."""
    ds = sift_small
    gt, _ = ds.ground_truth(K)
    for name, kind in RULES.items():
        rh = open_index(ds.X, index="flat", method=name, backend="host",
                        schedule=_policy()).search(ds.Q[:8], K)
        rj = open_index(ds.X, index="flat", method=name, backend="jax",
                        schedule=_policy()).search(ds.Q[:8], K)
        if kind in ("lb", "fdscan"):
            np.testing.assert_array_equal(rh.ids, rj.ids), name
        rec = recall_at_k(rj.ids, gt[:8])
        assert rec >= 0.9, (name, rec)
        if kind not in ("fdscan",):
            assert rj.stats.dims_scanned < rj.stats.dims_total, name


def test_stream_kernel_path_matches_jnp_path(sift_small):
    """The Pallas kernel (interpret mode here, compiled on TPU) and the jnp
    block path make identical screening decisions -> identical top-k."""
    ds = sift_small
    for name in ("PDScanning+", "ADSampling", "DDCopq"):
        m = _fitted(ds, name)
        dstate = m.device_state()
        kw = dict(kind=dstate["kind"], d1=48, k=K, query_chunk=8,
                  row_block=512, block_capacity=128)
        if dstate["kind"] == "opq":
            kw["theta"] = dstate["theta"]
        if dstate["kind"] == "adsampling":
            kw["eps0"] = dstate["eps0"]
        cfg = DcoEngineConfig(**kw, use_kernel=False)
        st = build_device_state(dstate, cfg.d1)
        if dstate["kind"] == "opq":
            st["codes"] = jnp.asarray(np.asarray(dstate["codes"]), jnp.int32)
        W = dstate.get("W")
        Q = np.asarray(ds.Q[:8] @ W if W is not None else ds.Q[:8], np.float32)
        qe = {}
        if dstate["kind"] == "opq":
            from repro.core import transforms as T
            pq = {"books": dstate["books"], "splits": dstate["splits"]}
            qe = {"lut": jnp.asarray(np.stack([T.pq_query_lut(pq, q)
                                               for q in Q]))}
        ql, qt = jnp.asarray(Q[:, :48]), jnp.asarray(Q[:, 48:])
        d0, i0, s0, p0, dm0, _ = stream_topk(st, ql, qt, cfg, qe)
        cfgk = dataclasses.replace(cfg, use_kernel=True)
        d1_, i1, s1, p1, dm1, _ = stream_topk(st, ql, qt, cfgk, qe)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1)), name
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1)), name


def test_stream_ragged_query_batch(sift_small):
    """nq not a multiple of query_chunk pads and slices correctly."""
    ds = sift_small
    sess = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                      schedule=_policy(query_chunk=4))
    r_full = sess.search(ds.Q[:8], K)           # aligned: 8 % 4 == 0
    r_ragged = sess.search(ds.Q[:7], K)         # ragged: 7 % 4 != 0
    assert r_ragged.ids.shape == (7, K)
    np.testing.assert_array_equal(r_ragged.ids, r_full.ids[:7])


def test_stream_corpus_not_multiple_of_row_block(sift_small):
    """N % row_block != 0: padding rows must never surface in the top-k."""
    ds = sift_small                              # 5000 rows
    m = make_method("PDScanning+").fit(ds.X)
    gt, _ = ds.ground_truth(K)
    Q = jnp.asarray(ds.Q[:8]) @ jnp.asarray(m.state["pca"]["W"])
    for rb in (384, 512, 4999, 8192):            # ragged, even, near-N, > N
        cfg = DcoEngineConfig(kind="lb", d1=48, k=K, query_chunk=8,
                              row_block=rb, block_capacity=128,
                              use_kernel=False)
        st = build_device_state(m, cfg.d1)
        d, i, s, p, dm, _ = stream_topk(st, Q[:, :cfg.d1], Q[:, cfg.d1:], cfg)
        assert (np.asarray(i) >= 0).all() and (np.asarray(i) < ds.n).all()
        assert recall_at_k(np.asarray(i), gt[:8]) == 1.0, rb


def test_stream_k_exceeds_block_capacity(sift_small):
    """k > block_capacity still returns a well-formed (and here complete)
    top-k: each block contributes at most block_capacity candidates but the
    carried top-k accumulates across blocks."""
    ds = sift_small
    m = make_method("PDScanning+").fit(ds.X)
    k = 32
    cfg = DcoEngineConfig(kind="lb", d1=48, k=k, query_chunk=8,
                          row_block=512, block_capacity=16, use_kernel=False)
    st = build_device_state(m, cfg.d1)
    Q = jnp.asarray(ds.Q[:8]) @ jnp.asarray(m.state["pca"]["W"])
    d, i, s, p, dm, _ = stream_topk(st, Q[:, :cfg.d1], Q[:, cfg.d1:], cfg)
    assert d.shape == (8, k) and np.isfinite(np.asarray(d)).all()
    assert (np.diff(np.asarray(d), axis=1) >= 0).all()      # sorted ascending
    gt, _ = ds.ground_truth(k)
    assert recall_at_k(np.asarray(i), gt[:8]) >= 0.95


def test_stream_truncation_is_certified():
    """Adversarial block-capacity overflow: many decoys with tiny stage-1
    lower bounds crowd the completion budget and push out the true
    neighbor.  The engine cannot avoid the (capacity-bounded) miss, but its
    exactness certificate MUST catch it: dropped_min_est <= kth distance.
    With a budget larger than the decoy set, the result is exact again and
    the certificate passes."""
    rng = np.random.default_rng(0)
    n, D, d1, k = 4096, 128, 48, 10
    X = rng.standard_normal((n, D)).astype(np.float32) * 4.0
    q = np.zeros(D, np.float32)
    # 300 decoys: lead distance ~1 (beats everyone at stage 1), tail huge
    X[:300, :d1] = rng.standard_normal((300, d1)).astype(np.float32) / 8.0
    X[:300, d1:] = 0.0
    X[:300, d1] = 10.0
    # true nearest neighbor: lead distance ~2, zero tail
    X[300] = 0.0
    X[300, 0] = 2.0
    st = {"x_lead": jnp.asarray(X[:, :d1]), "x_tail": jnp.asarray(X[:, d1:]),
          "lead_sq": jnp.asarray((X[:, :d1] ** 2).sum(1)),
          "tail_sq": jnp.asarray((X[:, d1:] ** 2).sum(1))}
    ql = jnp.asarray(q[None, :d1])
    qt = jnp.asarray(q[None, d1:])
    cfg = DcoEngineConfig(kind="lb", d1=d1, k=k, query_chunk=1,
                          row_block=4096, block_capacity=128,
                          use_kernel=False)
    d, i, s, p, dm, _ = stream_topk(st, ql, qt, cfg)
    assert 300 not in np.asarray(i)[0]                   # NN was truncated...
    assert float(dm[0]) <= float(d[0, -1])               # ...and flagged
    cfg2 = dataclasses.replace(cfg, block_capacity=512)  # budget > decoys
    d2, i2, s2, p2, dm2, _ = stream_topk(st, ql, qt, cfg2)
    assert np.asarray(i2)[0, 0] == 300 and float(d2[0, 0]) == 4.0
    assert float(dm2[0]) > float(d2[0, -1])              # certified exact


def test_jax_ivf_probe_matches_host(sift_small):
    """Device-side IVF probing selects the same partitions as the host index
    and completes the same exact top-k; recall grows with nprobe and hits
    1.0 at full probe."""
    ds = sift_small
    gt, _ = ds.ground_truth(K)
    params = {"n_list": 32}
    sh = open_index(ds.X, index="ivf", method="PDScanning+", backend="host",
                    schedule=_policy(), index_params=params)
    sj = open_index(ds.X, index="ivf", method="PDScanning+", backend="jax",
                    schedule=_policy(), index_params=params)
    recs = []
    for nprobe in (2, 8, 32):
        a = sh.search(ds.Q[:8], K, nprobe=nprobe)
        b = sj.search(ds.Q[:8], K, nprobe=nprobe)
        np.testing.assert_array_equal(a.ids, b.ids), nprobe
        assert b.stats.dims_scanned < b.stats.dims_total
        recs.append(recall_at_k(b.ids, gt[:8]))
    assert recs[0] <= recs[1] <= recs[2] == 1.0


def test_jax_ivf_rejects_mesh(sift_small):
    import jax
    from jax.sharding import Mesh
    ds = sift_small
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="single-device"):
        open_index(ds.X[:512], index="ivf", method="PDScanning+",
                   backend="jax", mesh=mesh)


def test_stream_survivor_stats_are_real(sift_small):
    """survivors_mean reflects actual stage-2 completions (bounded by what
    the running tau admits), not a capacity bound."""
    ds = sift_small
    res = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                     schedule=_policy()).search(ds.Q[:8], K)
    sm = res.stats.extra["survivors_mean"]
    assert 0 < sm < ds.n
    assert sm != min(512, ds.n)          # not the old capacity upper bound
    assert res.stats.extra["screen_pass_mean"] >= sm
    assert res.stats.extra["uncertified_queries"] == 0.0
