"""PDX vertical-layout coverage (DESIGN.md §8).

Parity: the dimension-grouped progressive scan must return bit-identical
top-k ids to the row-blocked stream engine (and the host scan) on every
draw — G=1 is the degenerate case and must be bitwise on distances too.
Certificate: every query either returns the exact brute-force top-k or has
its ``dropped_min_est`` certificate withdrawn; the adversarial decoy test
checks the R-cut's observer specifically (a drop that off-by-one-group
bookkeeping would silently lose).  Interactions: anytime deadlines, the LSM
delta segment, and the adaptive policy's verify-and-repair escape.

The hypothesis sweeps run only when hypothesis is installed (the plain
oracle tests below always run; tests/_hypothesis_compat.py skips just the
property tests otherwise).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SchedulePolicy, open_index
from repro.core.engine import (EXTRA_COVERAGE, EXTRA_DIMS_READ_MEAN,
                               EXTRA_UNCERTIFIED_MASK,
                               EXTRA_UNCERTIFIED_QUERIES)
from repro.core.jax_engine import DcoEngineConfig
from repro.core.policy import PolicyConfig
from repro.core.stream_engine import (_group_plan, build_stream_blocks,
                                      stream_topk)
from tests._hypothesis_compat import given, settings, st

K = 10


def _decayed(n, D, nq=5, seed=0, decay=12.0):
    """PCA-like spectrum: lead dims carry most energy, the regime where
    per-group early exit actually fires (isotropic data never crosses tau
    before ~d1 dims, so it exercises nothing)."""
    rng = np.random.default_rng(seed)
    s = np.exp(-np.arange(D) / decay).astype(np.float32)
    return ((rng.standard_normal((n, D)) * s).astype(np.float32),
            (rng.standard_normal((nq, D)) * s).astype(np.float32))


def _state(X, d1):
    return {"x_lead": jnp.asarray(X[:, :d1]), "x_tail": jnp.asarray(X[:, d1:]),
            "lead_sq": jnp.asarray((X[:, :d1] ** 2).sum(1)),
            "tail_sq": jnp.asarray((X[:, d1:] ** 2).sum(1))}


def _cfg(d1, k=K, **kw):
    base = dict(kind="lb", d1=d1, k=k, query_chunk=4, row_block=512,
                block_capacity=128, use_kernel=False)
    base.update(kw)
    return DcoEngineConfig(**base)


def _run(X, Q, cfg):
    st_ = _state(X, cfg.d1)
    out = stream_topk(st_, jnp.asarray(Q[:, :cfg.d1]),
                      jnp.asarray(Q[:, cfg.d1:]), cfg)
    return [np.asarray(v) for v in out]


def _brute(X, Q, k):
    d2 = ((X[None] - Q[:, None]) ** 2).sum(-1)
    i = np.argsort(d2, 1)[:, :k]
    return np.take_along_axis(d2, i, 1), i


# ------------------------------------------------------- group plan ---------
def test_group_plan_partitions_and_is_idempotent():
    """The split must cover d1 exactly with positive widths, and rebuilding
    a plan from its own resolved G must reproduce it (delta segments are
    rebuilt from the main layout's actual group count)."""
    for d1 in range(1, 70):
        for groups in range(1, 10):
            G, dg, widths = _group_plan(d1, groups)
            assert 1 <= G <= min(groups, d1)
            assert sum(widths) == d1 and all(w > 0 for w in widths)
            assert all(w <= dg for w in widths)
            assert _group_plan(d1, G) == (G, dg, widths)


# ----------------------------------------------------- parity sweep ---------
#: (n, D, d1, row_block, dim_groups, k) — ragged rows, ragged dim splits,
#: the G=1 degenerate, and k > block_capacity.
PARITY_CASES = [
    (1024, 96, 48, 256, 4, K),      # even splits
    (1000, 96, 48, 384, 5, K),      # N % row_block != 0, d1 % G != 0
    (777, 64, 40, 256, 3, K),       # everything ragged
    (600, 48, 48, 128, 4, K),       # no tail (d1 == D)
    (512, 96, 48, 512, 1, K),       # degenerate G=1: bitwise vs baseline
    (900, 96, 33, 200, 7, K),       # G close to group width 1
    (700, 96, 48, 128, 4, 200),     # k > block_capacity
]


@pytest.mark.parametrize("n,D,d1,rb,g,k", PARITY_CASES)
def test_pdx_matches_row_blocked_engine(n, D, d1, rb, g, k):
    bc = min(128, rb)
    base = _cfg(d1, k=k, row_block=rb, block_capacity=bc)
    pdx = dataclasses.replace(base, dim_groups=g)
    X, Q = _decayed(n, D, seed=n + g)
    d0, i0, s0, p0, dm0, r0 = _run(X, Q, base)
    d1_, i1, s1, p1, dm1, r1 = _run(X, Q, pdx)
    np.testing.assert_array_equal(i0, i1)       # ids bit-identical, always
    if g == 1:                                  # same code path: bitwise
        np.testing.assert_array_equal(d0, d1_)
        np.testing.assert_array_equal(np.asarray(dm0), np.asarray(dm1))
    else:                                       # grouped accumulation order
        np.testing.assert_allclose(d0, d1_, rtol=1e-5, atol=1e-5)
    # certificate soundness on BOTH engines: certified queries are exact
    bd, bi = _brute(X, Q, k)
    for qi in range(Q.shape[0]):
        if dm1[qi] > d1_[qi, -1]:
            np.testing.assert_array_equal(i1[qi], bi[qi])


def test_pdx_blocks_layout_guard():
    """Cached blocks built at one group count must be rejected by a cfg that
    resolves to another (the facade rebuilds; raw callers get a clear error
    instead of garbage gathers)."""
    X, Q = _decayed(512, 64, seed=3)
    st_ = _state(X, 32)
    blocks = build_stream_blocks(st_, 256, dim_groups=4)
    with pytest.raises(ValueError, match="dim group"):
        stream_topk(st_, jnp.asarray(Q[:, :32]), jnp.asarray(Q[:, 32:]),
                    _cfg(32, row_block=256), blocks=blocks)


@settings(max_examples=15, deadline=None)
@given(st.integers(64, 700), st.integers(2, 12), st.integers(1, 8),
       st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_pdx_parity_property(n, dim8, gfrac, rbfrac, seed):
    """Property sweep: for random corpus/query draws and random layout
    splits, PDX ids are bit-identical to the row-blocked engine and every
    certified query is exactly the brute-force top-k."""
    D = 8 * dim8
    d1 = max(1, D // 2)
    rb = max(64, n // rbfrac)
    g = min(gfrac, d1)
    k = min(K, n)
    X, Q = _decayed(n, D, nq=3, seed=seed % 10_000)
    base = _cfg(d1, k=k, row_block=rb, block_capacity=min(128, rb))
    d0, i0, *_ = _run(X, Q, base)
    d1_, i1, s1, p1, dm1, r1 = _run(X, Q, dataclasses.replace(
        base, dim_groups=g))
    np.testing.assert_array_equal(i0, i1)
    bd, bi = _brute(X, Q, k)
    for qi in range(Q.shape[0]):
        if dm1[qi] > d1_[qi, -1]:
            np.testing.assert_array_equal(i1[qi], bi[qi])


# ------------------------------------------------- adversarial decoys -------
def _decoy_corpus():
    """Block 0: 64 near rows (the eventual tau) plus far rows whose lead
    partial alone is enormous, so its completion cut only ever drops
    certified-prunable rows.  Block 1: 600 decoys whose group-0 partial is
    nearly zero but whose groups 1-2 carry a huge spike (they pass the
    screening read, then freeze mid-refinement), plus the true nearest
    neighbor whose group-0 partial is *worse* than every decoy — the auto
    R-cut (R=512 < 601) must drop it.  If the R-cut's observer were off by
    one group (or missing), the miss would go unflagged."""
    rng = np.random.default_rng(0)
    n0, nd, D, d1 = 2048, 600, 128, 48
    X = np.zeros((n0 + nd + 1, D), np.float32)
    X[:64] = rng.standard_normal((64, D)).astype(np.float32)   # exact ~ D
    X[64:n0, :d1] = 30.0                   # far: lead partial ~ 43k, huge
    X[n0:n0 + nd, :12] = rng.standard_normal((nd, 12)).astype(np.float32) / 8.0
    X[n0:n0 + nd, 12:36] = 20.0            # groups 1-2 spike (dg = 12)
    X[n0 + nd, 0] = 2.0                    # true NN: exact dist 4.0 to q=0
    q = np.zeros((1, D), np.float32)
    return X, q, n0 + nd, d1


def test_pdx_rcut_drop_is_flagged_not_silent():
    X, q, nn_id, d1 = _decoy_corpus()
    cfg = _cfg(d1, query_chunk=1, row_block=2048, block_capacity=64,
               dim_groups=4)                # auto R = max(4*64, 512) = 512
    d, i, s, p, dm, r = _run(X, q, cfg)
    assert nn_id not in i[0]                # the R-cut dropped the true NN...
    assert float(dm[0]) <= float(d[0, -1])  # ...and the certificate says so


def test_pdx_group_capacity_restores_exactness():
    X, q, nn_id, d1 = _decoy_corpus()
    cfg = _cfg(d1, query_chunk=1, row_block=2048, block_capacity=64,
               dim_groups=4, group_capacity=2048)    # R = B: no cut
    d, i, s, p, dm, r = _run(X, q, cfg)
    assert i[0, 0] == nn_id and float(d[0, 0]) == 4.0
    assert float(dm[0]) > float(d[0, -1])   # certified: nothing low dropped


def test_adaptive_repairs_pdx_rcut_drop():
    """The adaptive spill gate treats a finite R-cut drop like a capacity
    spill: the block escapes to the certified full completion, so the same
    corpus that the fixed PDX engine flags as a miss comes back exact."""
    X, q, nn_id, d1 = _decoy_corpus()
    cfg = _cfg(d1, query_chunk=1, row_block=2048, block_capacity=64,
               dim_groups=4, policy=PolicyConfig())
    d, i, s, p, dm, r, rep = _run(X, q, cfg)
    assert i[0, 0] == nn_id and float(d[0, 0]) == 4.0
    assert float(dm[0]) > float(d[0, -1])


# --------------------------------------------------- facade interactions ----
def _pol(**kw):
    base = dict(d1=48, query_chunk=4, row_block=256, block_capacity=256,
                dim_groups=4, use_kernel=False, anytime_block_group=2)
    base.update(kw)
    return SchedulePolicy(**base)


def test_pdx_host_and_jax_agree():
    X, Q = _decayed(1500, 96, seed=11)
    bd, bi = _brute(X, Q, K)
    rj = open_index(X, method="PDScanning", backend="jax",
                    schedule=_pol()).search(Q, K)
    rh = open_index(X, method="PDScanning", backend="host",
                    schedule=_pol(delta0=16, delta_d=16)).search(Q, K)
    np.testing.assert_array_equal(rj.ids, bi)
    np.testing.assert_array_equal(rh.ids, bi)
    assert rj.stats.extra[EXTRA_UNCERTIFIED_QUERIES] == 0.0
    # both paths measure dims actually read; early exit must beat a full
    # stage-1 read (d1 + completed tails) on this spectrum
    assert 0.0 < rj.stats.extra[EXTRA_DIMS_READ_MEAN] < 48.0
    assert 0.0 < rh.stats.extra[EXTRA_DIMS_READ_MEAN] < 96.0


def test_pdx_dims_read_smaller_than_flat():
    X, Q = _decayed(2000, 96, seed=13)
    r1 = open_index(X, method="PDScanning", backend="jax",
                    schedule=_pol(dim_groups=1)).search(Q, K)
    r4 = open_index(X, method="PDScanning", backend="jax",
                    schedule=_pol()).search(Q, K)
    np.testing.assert_array_equal(r1.ids, r4.ids)
    assert (r4.stats.extra[EXTRA_DIMS_READ_MEAN]
            < r1.stats.extra[EXTRA_DIMS_READ_MEAN])


def test_pdx_anytime_generous_deadline_bit_identical():
    X, Q = _decayed(1200, 96, seed=17)
    sess = open_index(X, method="PDScanning", backend="jax", schedule=_pol())
    r0 = sess.search(Q, K)
    r1 = sess.search(Q, K, deadline_s=1e6)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.dists, r1.dists)
    assert (r1.stats.extra[EXTRA_COVERAGE] == 1.0).all()
    assert not r1.stats.extra[EXTRA_UNCERTIFIED_MASK].any()


def test_pdx_anytime_expiry_withdraws_certificate():
    from repro.testing import faults
    X, Q = _decayed(2048, 96, seed=19)
    pol = _pol(row_block=256, anytime_block_group=1)
    sess = open_index(X, method="PDScanning", backend="jax", schedule=pol)
    sess.search(Q, K)                       # warm the jit cache
    with faults.inject(slow_block_s=0.05):
        res = sess.search(Q, K, deadline_s=0.01)
    cov = res.stats.extra[EXTRA_COVERAGE]
    assert (cov < 1.0).all() and (cov > 0.0).all()
    assert res.stats.extra[EXTRA_UNCERTIFIED_MASK].all()


def test_pdx_delta_segment_matches_merged():
    X, Q = _decayed(1100, 96, seed=23)
    Xnew = X[:64] * 1.01
    sess = open_index(X[64:], method="PDScanning", backend="jax",
                      schedule=_pol())
    sess.search(Q, K)                       # materialize the main layout
    sess.add(Xnew)
    assert sess.last_write_mode == "delta"  # grouped layout kept, delta added
    r_delta = sess.search(Q, K)
    merged = open_index(np.concatenate([X[64:], Xnew]), method="PDScanning",
                        backend="jax", schedule=_pol())
    r_full = merged.search(Q, K)
    np.testing.assert_array_equal(r_delta.ids, r_full.ids)
    np.testing.assert_allclose(r_delta.dists, r_full.dists,
                               rtol=1e-5, atol=1e-5)


def test_pdx_kernel_path_matches_jnp(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    X, Q = _decayed(800, 96, seed=29)
    base = _cfg(48, row_block=256, block_capacity=256, dim_groups=4)
    dj, ij, *_ = _run(X, Q, base)
    dk, ik, sk, pk, dmk, rk = _run(X, Q, dataclasses.replace(
        base, use_kernel=True))
    np.testing.assert_array_equal(ij, ik)
    np.testing.assert_allclose(dj, dk, rtol=1e-5, atol=1e-5)
    bd, bi = _brute(X, Q, K)
    np.testing.assert_array_equal(ik, bi)
