"""Behavioural tests for the 8 DCO methods (paper §III semantics)."""
import numpy as np
import pytest

from repro.core.engine import QueryBatch, ScanStats, make_schedule, scan_topk
from repro.core.methods import ALL_METHODS, BASELINES, make_method
from repro.vecdata.synthetic import recall_at_k

K = 10
NQ = 8


def _fit(name, ds, schedule):
    m = make_method(name).fit(ds.X)
    if m.needs_training:
        rng = np.random.default_rng(1)
        m.train(ds.X[rng.choice(ds.n, 16)], K, schedule)
    return m


@pytest.mark.parametrize("name", list(ALL_METHODS))
def test_full_scan_topk_recall(name, sift_small):
    ds = sift_small
    sched = make_schedule(ds.dim)
    m = _fit(name, ds, sched)
    stats = ScanStats()
    batch = QueryBatch.create(m, ds.Q[:NQ], sched, stats)
    gt, _ = ds.ground_truth(K)
    found = []
    for qi in range(NQ):
        _, ids = scan_topk(m, batch, qi, np.arange(ds.n), K)
        found.append(ids)
    rec = recall_at_k(np.array(found), gt[:NQ])
    if m.exact:
        assert rec == 1.0, f"{name} must be exact, got {rec}"
    else:
        assert rec >= 0.95, f"{name} recall {rec} too low"
    if name != "FDScanning":
        assert stats.pruning_ratio > 0.2, f"{name} prunes nothing"


def test_exact_methods_agree(sift_small):
    ds = sift_small
    sched = make_schedule(ds.dim)
    res = {}
    for name in BASELINES:
        m = _fit(name, ds, sched)
        batch = QueryBatch.create(m, ds.Q[:4], sched)
        d, i = scan_topk(m, batch, 0, np.arange(ds.n), K)
        res[name] = (d, i)
    for name in BASELINES[1:]:
        np.testing.assert_allclose(res[name][0], res["FDScanning"][0], rtol=1e-4)


def test_append_consistency(sift_small):
    """Dynamic insert (paper §V-E): append == refit for scanning methods."""
    ds = sift_small
    half = ds.n // 2
    sched = make_schedule(ds.dim)
    m = make_method("PDScanning+").fit(ds.X[:half])
    m.append(ds.X[half:])
    m2 = make_method("PDScanning+", pca=m.state["pca"]).fit(ds.X)
    b1 = QueryBatch.create(m, ds.Q[:2], sched)
    b2 = QueryBatch.create(m2, ds.Q[:2], sched)
    d1, i1 = scan_topk(m, b1, 0, np.arange(ds.n), K)
    d2, i2 = scan_topk(m2, b2, 0, np.arange(ds.n), K)
    np.testing.assert_allclose(d1, d2, rtol=1e-4)
    assert set(i1) == set(i2)


def test_ip_metric_via_normalization(sift_small):
    """Eq. 8: IP on normalized vectors == monotone transform of L2."""
    ds = sift_small.normalized()
    q = ds.Q[0]
    ip = ds.X @ q
    d2 = ((ds.X - q) ** 2).sum(1)
    np.testing.assert_allclose(ip, 1.0 - 0.5 * d2 * (q @ q + 1) / (q @ q + 1),
                               atol=1e-3)
    # top-k by IP == top-k by L2 on normalized data
    k_ip = set(np.argsort(-ip)[:K].tolist())
    k_l2 = set(np.argsort(d2)[:K].tolist())
    assert k_ip == k_l2


def test_pruning_increases_with_dim_on_pca(sift_small):
    """More scanned dims => (weakly) more pruning for PDScanning+."""
    ds = sift_small
    m = make_method("PDScanning+").fit(ds.X)
    ctx = m.prep_queries(ds.Q[:4])
    gt, gtd = ds.ground_truth(K)
    tau = float(gtd[0, -1])
    keep16, _ = m.screen(np.arange(ds.n), ctx, 0, 16, tau)
    keep64, _ = m.screen(np.arange(ds.n), ctx, 0, 64, tau)
    assert keep64.sum() <= keep16.sum()
    # exactness: every true neighbor survives
    assert keep64[gt[0]].all()
