"""Serving front + LSM delta write path (PR 6).

Parity convention: the delta layout must answer exactly like a merged
layout over the same fitted state, so every comparison reuses the SAME
method object (a freshly ``open_index``-ed session would refit transforms
on the grown corpus and legitimately differ).  Certified configurations
only (adaptive policy, or block_capacity == row_block): the streaming
certificate guarantees exact answers there, making ids comparable bit-wise.
"""
import numpy as np
import pytest

from repro.api import SchedulePolicy, SearchSession, open_index
from repro.core.engine import EXTRA_UNCERTIFIED_MASK


def _data(n=1536, d=48, nq=12, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(nq, d)).astype(np.float32))


def _pol(**kw):
    kw.setdefault("d1", 24)
    kw.setdefault("query_chunk", 4)
    kw.setdefault("row_block", 256)
    kw.setdefault("block_capacity", 256)
    return SchedulePolicy(**kw)


# ---------------------------------------------------------------- add() ----
def test_add_validates_dimension_and_dtype():
    X, _ = _data()
    sess = open_index(X, index="flat", method="PDScanning", backend="host")
    with pytest.raises(ValueError, match="dimension 47"):
        sess.add(np.zeros((3, 47), np.float32))
    with pytest.raises(ValueError, match="numeric"):
        sess.add(np.array([["a"] * X.shape[1]]))
    with pytest.raises(ValueError, match="shape"):
        sess.add(np.zeros((2, 3, X.shape[1]), np.float32))
    sess.add(np.zeros((2, X.shape[1]), np.float64))   # numeric casts are fine
    assert sess.n == X.shape[0] + 2


# ------------------------------------------------- flat delta segment ------
@pytest.mark.parametrize("policy_kw", [{}, {"adaptive": True}])
def test_flat_delta_matches_merged_layout(policy_kw):
    X, Q = _data()
    pol = _pol(**policy_kw)
    sess = open_index(X[:1200], index="flat", method="PDScanning+",
                      backend="jax", schedule=pol)
    sess.search(Q, 10)
    n_main0 = sess.backend._n_main
    written0 = sess.backend.rows_written
    sess.add(X[1200:])
    assert sess.last_write_mode == "delta"
    rd = sess.search(Q, 10)
    # the acceptance regression: an insert below the merge threshold must
    # NOT re-materialize the main layout — only the delta rows are written
    assert sess.backend._n_main == n_main0
    assert sess.backend.merges == 0
    assert sess.backend.rows_written == written0 + (X.shape[0] - 1200)
    assert sess.backend.delta_rows == X.shape[0] - 1200
    merged = SearchSession(sess.method, "flat", None, "jax", pol)
    rm = merged.search(Q, 10)
    np.testing.assert_array_equal(rd.ids, rm.ids)
    np.testing.assert_allclose(rd.dists, rm.dists, rtol=1e-5, atol=1e-5)
    # certified exact: the delta scan keeps the per-query certificate
    assert not rd.stats.extra[EXTRA_UNCERTIFIED_MASK].any()


def test_flat_delta_matches_host_backend():
    X, Q = _data()
    pol = _pol(adaptive=True)
    sess = open_index(X[:1200], index="flat", method="DADE",
                      backend="jax", schedule=pol)
    sess.add(X[1200:])
    rj = sess.search(Q, 10)
    host = SearchSession(sess.method, "flat", None, "host", pol)
    rh = host.search(Q, 10)
    np.testing.assert_array_equal(rj.ids, rh.ids)


def test_repeated_adds_accumulate_in_delta():
    X, Q = _data()
    sess = open_index(X[:1200], index="flat", method="PDScanning+",
                      backend="jax", schedule=_pol())
    sess.search(Q, 5)
    for lo in range(1200, X.shape[0], 112):
        sess.add(X[lo:lo + 112])
        assert sess.last_write_mode == "delta"
    rd = sess.search(Q, 5)
    rm = SearchSession(sess.method, "flat", None, "jax", _pol()).search(Q, 5)
    np.testing.assert_array_equal(rd.ids, rm.ids)


# --------------------------------------------------------- IVF delta -------
def test_ivf_delta_matches_host_backend():
    X, Q = _data()
    pol = _pol(adaptive=True)
    sess = open_index(X[:1200], index="ivf", method="PDScanning+",
                      backend="jax", schedule=pol,
                      index_params={"n_list": 16})
    sess.search(Q, 10, nprobe=16)                 # warm the main layout
    n_main0 = sess.backend._n_main
    sess.add(X[1200:])
    assert sess.last_write_mode == "delta"
    rj = sess.search(Q, 10, nprobe=16)            # nprobe = n_list: exact
    assert sess.backend._n_main == n_main0
    host = SearchSession(sess.method, "ivf", sess.index, "host", pol)
    rh = host.search(Q, 10, nprobe=16)
    np.testing.assert_array_equal(rj.ids, rh.ids)
    np.testing.assert_allclose(rj.dists, rh.dists, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- merge policy ------
def test_merge_threshold_triggers_rematerialization():
    X, Q = _data()
    pol = _pol(delta_merge_threshold=200)
    sess = open_index(X[:1200], index="flat", method="PDScanning+",
                      backend="jax", schedule=pol)
    sess.search(Q, 10)
    sess.add(X[1200:1350])
    assert sess.last_write_mode == "delta"
    sess.add(X[1350:])                            # delta would exceed 200
    assert sess.last_write_mode == "merge"
    assert sess.backend.merges == 1
    rd = sess.search(Q, 10)
    assert sess.backend._n_main == X.shape[0]     # fully merged
    assert sess.backend.delta_rows == 0
    rm = SearchSession(sess.method, "flat", None, "jax", pol).search(Q, 10)
    np.testing.assert_array_equal(rd.ids, rm.ids)


def test_zero_threshold_disables_delta_path():
    X, Q = _data()
    pol = _pol(delta_merge_threshold=0)
    sess = open_index(X[:1200], index="flat", method="PDScanning+",
                      backend="jax", schedule=pol)
    sess.search(Q, 10)
    sess.add(X[1200:])
    assert sess.last_write_mode == "rebuild"      # pre-PR-6 behavior
    sess.search(Q, 10)
    assert sess.backend._n_main == X.shape[0]


# -------------------------------------------------------- persistence ------
def test_save_load_with_nonempty_delta(tmp_path):
    X, Q = _data()
    sess = open_index(X[:1200], index="flat", method="PDScanning+",
                      backend="jax", schedule=_pol())
    sess.search(Q, 10)
    sess.add(X[1200:])
    assert sess.backend.delta_rows > 0
    before = sess.search(Q, 10)
    sess.save(tmp_path / "idx.bin")
    loaded = SearchSession.load(tmp_path / "idx.bin", backend="jax")
    after = loaded.search(Q, 10)
    assert loaded.n == X.shape[0]
    np.testing.assert_array_equal(before.ids, after.ids)


# ------------------------------------------------------ SearchService ------
def test_service_batches_match_batched_search():
    X, Q = _data(nq=11)                           # < slots and > slots below
    sess = open_index(X, index="flat", method="PDScanning+",
                      backend="jax", schedule=_pol(adaptive=True))
    svc = sess.serve(slots=4, k=10)
    reqs = [svc.submit(q) for q in Q]
    assert svc.pending == len(Q)
    served = svc.drain()
    assert svc.pending == 0 and len(served) == len(Q)
    ref = sess.search(Q, 10)
    for i, r in enumerate(reqs):
        assert r.done and r.latency_s >= 0.0
        assert r.certified is True                # adaptive => certified
        assert r.batch_size <= 4 and r.n_visible == X.shape[0]
        np.testing.assert_array_equal(r.ids, ref.ids[i])


def test_service_rejects_bad_dimension_and_empty_step():
    X, _ = _data()
    svc = open_index(X, index="flat", method="PDScanning", backend="host",
                     serving=True, serving_params={"slots": 2, "k": 5})
    assert svc.step() == []
    with pytest.raises(ValueError, match="dimension"):
        svc.submit(np.zeros(7, np.float32))


def test_service_interleaved_add_becomes_visible():
    X, Q = _data()
    sess = open_index(X[:1400], index="flat", method="PDScanning+",
                      backend="jax", schedule=_pol(adaptive=True))
    svc = sess.serve(slots=4, k=5)
    svc.submit(Q[0])
    first = svc.drain()[0]
    assert first.n_visible == 1400
    probe = X[1400]                               # insert, then query it
    info = svc.add(X[1400:])
    assert info["rows"] == X.shape[0] - 1400 and info["mode"] == "delta"
    svc.submit(probe)
    req = svc.drain()[0]
    assert req.n_visible == X.shape[0]
    assert req.ids[0] == 1400                     # its own row wins top-1
    assert req.dists[0] <= 1e-4


def test_service_simulated_time_stamps():
    X, Q = _data()
    svc = open_index(X, index="flat", method="PDScanning", backend="host",
                     serving=True, serving_params={"slots": 4, "k": 5})
    r0 = svc.submit(Q[0], now=10.0)
    r1 = svc.submit(Q[1], now=10.5)
    served = svc.drain(now=11.0)
    assert [r.rid for r in served] == [r0.rid, r1.rid]
    assert r0.t_submit == 10.0 and r1.t_submit == 10.5
    assert r0.t_done == pytest.approx(11.0 + r0.service_s)
    assert r0.latency_s > r1.latency_s            # same batch, earlier submit


# ------------------------------------------------------------ helpers ------
def test_latency_percentiles_shape():
    from benchmarks.common import latency_percentiles
    p = latency_percentiles(np.linspace(0.001, 0.1, 100))
    assert set(p) == {"p50_ms", "p95_ms", "p99_ms"}
    assert p["p50_ms"] <= p["p95_ms"] <= p["p99_ms"]
    assert p["p99_ms"] == pytest.approx(99.01, abs=0.5)
    with pytest.raises(ValueError):
        latency_percentiles([])
