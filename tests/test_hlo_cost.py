"""Unit tests for the trip-count-weighted HLO cost model (the roofline's
foundation) — parser + charging rules on a handcrafted module, plus an
end-to-end check against a real compiled artifact if one is present."""
import glob
import os

import pytest

from repro.launch.hlo_cost import HloCost, analyze_hlo, VMEM_CAP

MINI = """\
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (p0: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p0 = (s32[], f32[128,128]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%p0), index=0
  %gte1 = f32[128,128]{1,0} get-tuple-element(%p0), index=1
  %dot.1 = f32[128,128]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%dot.1), to_apply=%add.c
  %c1 = s32[] constant(1)
  %add.1 = s32[] add(%gte0, %c1)
  ROOT %tup = (s32[], f32[128,128]{1,0}) tuple(%add.1, %ar)
}

%cond.1 (p0: (s32[], f32[128,128])) -> pred[] {
  %p0 = (s32[], f32[128,128]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%p0), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%gte0, %c), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[128,128]{1,0}) tuple(%c0, %a)
  %w = (s32[], f32[128,128]{1,0}) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_trip_count_weighting():
    t = analyze_hlo(MINI)
    # dot: 2 * 128*128 * 128 flops, x10 trips
    assert t["flops"] == pytest.approx(10 * 2 * 128 * 128 * 128, rel=0.2)
    # all-reduce result 64KB x 10
    assert t["collective_bytes"] == pytest.approx(10 * 128 * 128 * 4, rel=0.01)
    assert t["unknown_trip_whiles"] == 0


def test_parser_finds_computations():
    hc = HloCost(MINI)
    assert hc.entry == "main"
    assert "body.1" in hc.comps and "cond.1" in hc.comps
    ops = {o["opcode"] for o in hc.comps["body.1"]}
    assert "dot" in ops and "all-reduce" in ops


def test_vmem_residency_charging():
    """Small in-body intermediates are free; parameter reads are charged."""
    t = analyze_hlo(MINI)
    # per trip: dot reads gte (loop carry: charged 64KB x2 operands)
    # + all-reduce result; the dot result (64KB < VMEM_CAP) result is free.
    assert t["bytes"] <= t["bytes_upper"]
    assert t["bytes"] > 0


@pytest.mark.skipif(not glob.glob("artifacts/dryrun/hlo/*.hlo.zst"),
                    reason="no saved dry-run HLO artifacts")
def test_real_artifact_roundtrip():
    import zstandard
    path = sorted(glob.glob("artifacts/dryrun/hlo/*.hlo.zst"))[0]
    hlo = zstandard.ZstdDecompressor().decompress(
        open(path, "rb").read(), max_output_size=2 ** 31).decode()
    t = analyze_hlo(hlo)
    assert t["flops"] > 0 and t["bytes"] > 0
    assert t["unknown_trip_whiles"] == 0       # every scan annotated
