"""Training substrate: optimizer, microbatching, checkpoint/restart,
fault tolerance, elastic re-mesh planning, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import TokenPipeline, make_batch_fn
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.fault import StepMonitor, plan_elastic_remesh, run_resumable
from repro.train.train_step import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _setup(arch="olmo-1b", **kw):
    cfg = smoke_config(arch)
    api = build_model(cfg, remat="none")
    state = init_state(api, KEY)
    step = jax.jit(make_train_step(api, **kw))
    def batch_fn(s):
        rng = np.random.default_rng(s)
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                      jnp.int32)}
    return cfg, api, state, step, batch_fn


def test_loss_decreases():
    cfg, api, state, _, batch_fn = _setup()
    step = jax.jit(make_train_step(api, lr_fn=lambda s: 3e-3))  # skip warmup
    losses = []
    fixed = batch_fn(0)
    for s in range(12):
        state, m = step(state, fixed)          # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation must match the single-shot gradient."""
    cfg, api, state, _, batch_fn = _setup()
    step1 = jax.jit(make_train_step(api, microbatches=1))
    step4 = jax.jit(make_train_step(api, microbatches=4))
    b = batch_fn(3)
    s1, m1 = step1(state, b)
    s4, m4 = step4(state, b)
    # same loss and nearly identical updated params
    d = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 5e-3, d


def test_checkpoint_roundtrip(tmp_path):
    cfg, api, state, step, batch_fn = _setup()
    state, _ = step(state, batch_fn(0))
    ckpt.save(state, str(tmp_path), 1)
    restored, s = ckpt.restore(state, str(tmp_path))
    assert s == 1
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_is_bitwise_identical(tmp_path):
    """Crash at step 6, resume, and land on the same final loss as an
    uninterrupted run (deterministic data + stateless batch_fn)."""
    cfg, api, state0, step, batch_fn = _setup()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    # uninterrupted
    ref, _ = run_resumable(step, state0, batch_fn, steps=10, ckpt_dir=d1,
                           ckpt_every=3)
    # crash + resume
    with pytest.raises(RuntimeError):
        run_resumable(step, state0, batch_fn, steps=10, ckpt_dir=d2,
                      ckpt_every=3, fail_at=6)
    resumed, last = run_resumable(step, state0, batch_fn, steps=10, ckpt_dir=d2,
                                  ckpt_every=3)
    assert last == 9
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_gc_and_async(tmp_path):
    cfg, api, state, step, batch_fn = _setup()
    for s in range(5):
        ckpt.save_async(state, str(tmp_path), s, keep_last=2)
    ckpt.wait_pending()
    steps = ckpt.latest_steps(str(tmp_path))
    assert len(steps) <= 2 and max(steps) == 4


def test_straggler_monitor():
    mon = StepMonitor(ratio=2.0)
    for _ in range(5):
        mon.record(0, 0.1)
    assert not mon.record(5, 0.15)
    assert mon.record(6, 1.0)            # 10x slower => flagged
    assert len(mon.stragglers) == 1


def test_plan_elastic_remesh():
    (dp, tp), lost = plan_elastic_remesh((16, 16), ("data", "model"), lost=3)
    assert tp == 16 and dp == 15 and lost == 1
    (dp, tp), lost = plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"),
                                         lost=17)
    assert tp == 16 and dp == 30 and lost == 2
    with pytest.raises(RuntimeError):
        plan_elastic_remesh((1, 4), ("data", "model"), lost=999)


def test_pipeline_deterministic_and_prefetches():
    cfg = smoke_config("olmo-1b")
    from repro.configs.base import RunShape
    fn = make_batch_fn(cfg, RunShape("t", 16, 2, "train"), seed=7)
    a = fn(5)["tokens"]
    b = fn(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    pipe = TokenPipeline(fn, depth=2)
    seen = [s for s, _ in pipe.iter(0, 5)]
    assert seen == list(range(5))
