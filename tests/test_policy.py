"""Adaptive DCO policy engine coverage (core.policy, both engines, facade).

The contract under test (DESIGN.md §5): adaptive mode never changes exact-rule
results (fallback and repair only ADD scanned dims), an OOD batch provably
triggers the fallback while matching fdscan exactly, the verify-and-repair
guard fixes the capacity-overflow miss PR 2's certificate could only flag,
and both backends report the same telemetry keys."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SchedulePolicy, open_index
from repro.core.engine import (EXTRA_EST_SAVED_FLOPS, EXTRA_FALLBACK_BLOCKS,
                               EXTRA_RULE_TIMELINE, EXTRA_SCREEN_PASS_MEAN,
                               EXTRA_SURVIVORS_MEAN,
                               EXTRA_UNCERTIFIED_QUERIES)
from repro.core.jax_engine import DcoEngineConfig
from repro.core.policy import HostPolicy, PolicyConfig, pass_threshold
from repro.core.stream_engine import stream_topk
from repro.vecdata.synthetic import make_ood_queries, recall_at_k

K = 10

ADAPTIVE_KEYS = (EXTRA_FALLBACK_BLOCKS, EXTRA_EST_SAVED_FLOPS,
                 EXTRA_RULE_TIMELINE)


def _policy(**kw):
    base = dict(d1=48, query_chunk=8, capacity=512, row_block=512,
                block_capacity=128)
    base.update(kw)
    return SchedulePolicy(**base)


def _gt(X, Q, k=K):
    d2 = (X ** 2).sum(1)[None, :] - 2.0 * Q @ X.T + (Q ** 2).sum(1)[:, None]
    return np.argsort(d2, axis=1)[:, :k]


# ---------------------------------------------------------------------------
# cost model + host decision unit tests
# ---------------------------------------------------------------------------

def test_pass_threshold_cost_model():
    """Threshold falls with margin, vanishes when screening can't pay."""
    t1 = pass_threshold(200, 48, 152, 1.0, 8.0)
    t2 = pass_threshold(200, 48, 152, 1.3, 8.0)
    assert 0.0 < t2 < t1 < 1.0
    # screening width ~ D: can never pay -> always-fallback threshold
    assert pass_threshold(200, 196, 4, 1.1, 8.0) <= 0.0
    # nearly-free screen with cheap completion: never falls back
    assert pass_threshold(200, 1, 10, 1.0, 0.0) >= 1.0


def test_host_policy_hysteresis_and_recovery():
    """Mode enters above the threshold, exits only below the hysteresis
    band, and the telemetry counts what was actually served."""
    cfg = PolicyConfig(fallback_margin=1.0, ewma_alpha=1.0, overhead_dims=0.0,
                       hysteresis=0.5)
    hp = HostPolicy(cfg, D=100)
    thr = pass_threshold(100, 10, 100, 1.0, 0.0)      # 0.9
    hp.observe(100, 95, 10.0)                         # frac 0.95 > thr
    assert hp.mode
    hp.observe(100, 60, 10.0)     # 0.6 > thr*hyst=0.45 -> stays in fallback
    assert hp.mode
    hp.observe(100, 20, 10.0)                         # 0.2 < 0.45 -> recovers
    assert not hp.mode
    hp.block_served(True, 100, 100, 10.0)
    hp.block_served(False, 100, 5, 10.0)
    assert hp.fallback_blocks == 1 and hp.timeline == [True, False]


# ---------------------------------------------------------------------------
# jax streaming engine
# ---------------------------------------------------------------------------

def test_adaptive_bit_identical_on_id_queries(sift_small):
    """Acceptance: on exact rules with in-distribution queries the adaptive
    session returns bit-identical ids AND distances to the fixed session,
    and the policy never fires."""
    ds = sift_small
    r0 = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                    schedule=_policy()).search(ds.Q[:8], K)
    r1 = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                    schedule=_policy(adaptive=True)).search(ds.Q[:8], K)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.dists, r1.dists)
    assert r1.stats.extra[EXTRA_FALLBACK_BLOCKS] == 0.0
    assert all(v == 0.0 for v in r1.stats.extra[EXTRA_RULE_TIMELINE])
    assert r1.stats.extra[EXTRA_EST_SAVED_FLOPS] > 0.0


def test_adaptive_ood_triggers_fallback_and_matches_fdscan(sift_small):
    """Acceptance: an adversarial OOD batch provably triggers the fallback
    (fallback_blocks > 0) while still matching fdscan ids exactly; the same
    batch through the fixed rule is flagged uncertified."""
    ds = sift_small
    Qo = make_ood_queries(ds.X, 8, severity=1.0)
    ra = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                    schedule=_policy(adaptive=True)).search(Qo, K)
    assert ra.stats.extra[EXTRA_FALLBACK_BLOCKS] > 0
    assert ra.stats.extra[EXTRA_UNCERTIFIED_QUERIES] == 0.0
    rf = open_index(ds.X, index="flat", method="FDScanning", backend="jax",
                    schedule=_policy()).search(Qo, K)
    np.testing.assert_array_equal(ra.ids, rf.ids)
    assert recall_at_k(ra.ids, _gt(ds.X, Qo)) == 1.0
    # the fixed rule on the same batch overflows its completion budget and
    # cannot certify its answers — the situation the policy exists to avoid
    rfix = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                      schedule=_policy()).search(Qo, K)
    assert rfix.stats.extra[EXTRA_UNCERTIFIED_QUERIES] > 0.0


def test_adaptive_repairs_capacity_overflow_miss():
    """The verify-and-repair guard: the adversarial decoy corpus of
    tests/test_stream_engine.py (capacity overflow pushes the true neighbor
    out of the completion budget) is a flagged MISS for the fixed engine —
    the adaptive engine must re-complete the unsafe block and return the
    exact answer with an intact certificate."""
    rng = np.random.default_rng(0)
    n, D, d1, k = 4096, 128, 48, 10
    X = rng.standard_normal((n, D)).astype(np.float32) * 4.0
    q = np.zeros(D, np.float32)
    X[:300, :d1] = rng.standard_normal((300, d1)).astype(np.float32) / 8.0
    X[:300, d1:] = 0.0
    X[:300, d1] = 10.0
    X[300] = 0.0
    X[300, 0] = 2.0
    st = {"x_lead": jnp.asarray(X[:, :d1]), "x_tail": jnp.asarray(X[:, d1:]),
          "lead_sq": jnp.asarray((X[:, :d1] ** 2).sum(1)),
          "tail_sq": jnp.asarray((X[:, d1:] ** 2).sum(1))}
    ql, qt = jnp.asarray(q[None, :d1]), jnp.asarray(q[None, d1:])
    cfg = DcoEngineConfig(kind="lb", d1=d1, k=k, query_chunk=1,
                          row_block=4096, block_capacity=128,
                          use_kernel=False)
    d0, i0, _, _, dm0, _ = stream_topk(st, ql, qt, cfg)
    assert 300 not in np.asarray(i0)[0]              # fixed engine: miss...
    assert float(dm0[0]) <= float(d0[0, -1])         # ...flagged, not fixed
    cfga = dataclasses.replace(cfg, policy=PolicyConfig())
    d1_, i1, s1, p1, dm1, _, rep = stream_topk(st, ql, qt, cfga)
    assert np.asarray(i1)[0, 0] == 300 and float(d1_[0, 0]) == 4.0
    assert not np.isfinite(float(dm1[0]))            # repaired: nothing dropped
    assert float(np.asarray(rep["fallback_blocks"])[0]) > 0


def test_adaptive_ragged_batch_matches_aligned(sift_small):
    """Padding queries must not perturb chunk-level decisions or results."""
    ds = sift_small
    sess = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                      schedule=_policy(query_chunk=4, adaptive=True))
    r_full = sess.search(ds.Q[:8], K)
    r_ragged = sess.search(ds.Q[:7], K)
    assert r_ragged.ids.shape == (7, K)
    np.testing.assert_array_equal(r_ragged.ids, r_full.ids[:7])


def test_adaptive_estimator_rule_stays_reasonable(sift_small):
    """Estimator rules under the policy: the fallback can only add exactly
    completed rows, so OOD recall must not fall below the fixed rule's."""
    ds = sift_small
    Qo = make_ood_queries(ds.X, 8, severity=1.0)
    gt = _gt(ds.X, Qo)
    rfix = open_index(ds.X, index="flat", method="DADE", backend="jax",
                      schedule=_policy()).search(Qo, K)
    rada = open_index(ds.X, index="flat", method="DADE", backend="jax",
                      schedule=_policy(adaptive=True)).search(Qo, K)
    assert recall_at_k(rada.ids, gt) >= recall_at_k(rfix.ids, gt)
    assert rada.stats.extra[EXTRA_FALLBACK_BLOCKS] > 0


def test_adaptive_mesh_rejected(sift_small):
    import jax
    from jax.sharding import Mesh
    ds = sift_small
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="single-device"):
        open_index(ds.X[:512], index="flat", method="PDScanning+",
                   backend="jax", mesh=mesh,
                   schedule=_policy(adaptive=True))


# ---------------------------------------------------------------------------
# host engine + cross-backend telemetry
# ---------------------------------------------------------------------------

def test_adaptive_telemetry_present_on_both_backends(sift_small):
    """Both backends report the canonical extra keys with the same names
    (api.types.STAT_EXTRA_KEYS) so host and jax runs are comparable."""
    ds = sift_small
    Qo = make_ood_queries(ds.X, 8, severity=1.0)
    for backend in ("host", "jax"):
        res = open_index(ds.X, index="flat", method="PDScanning+",
                         backend=backend,
                         schedule=_policy(adaptive=True)).search(Qo, K)
        ex = res.stats.extra
        for key in ADAPTIVE_KEYS + (EXTRA_SURVIVORS_MEAN,
                                    EXTRA_SCREEN_PASS_MEAN,
                                    EXTRA_UNCERTIFIED_QUERIES):
            assert key in ex, (backend, key)
        assert ex[EXTRA_FALLBACK_BLOCKS] > 0, backend
        assert isinstance(ex[EXTRA_RULE_TIMELINE], list)
        assert recall_at_k(res.ids, _gt(ds.X, Qo)) == 1.0, backend


def test_host_adaptive_identical_results_and_ivf(sift_small):
    """Host fallback only ever adds scanned dims, so flat AND IVF results
    are identical with the policy on; the shadow screen's extra dims are
    charged to dims_scanned."""
    ds = sift_small
    Qo = make_ood_queries(ds.X, 6, severity=1.0)
    for index in ("flat", "ivf"):
        # full probe on ivf: enough candidate blocks for the host policy's
        # history-based decision to engage
        r0 = open_index(ds.X, index=index, method="PDScanning+",
                        backend="host",
                        schedule=_policy()).search(Qo, K, nprobe=64)
        r1 = open_index(ds.X, index=index, method="PDScanning+",
                        backend="host",
                        schedule=_policy(adaptive=True)).search(Qo, K, nprobe=64)
        np.testing.assert_array_equal(r0.ids, r1.ids), index
        assert r1.stats.extra[EXTRA_FALLBACK_BLOCKS] > 0, index
        assert len(r1.stats.extra[EXTRA_RULE_TIMELINE]) > 0, index
