#!/usr/bin/env python
"""Relative-link checker for the markdown doc tree.

Usage: ``python tools/check_links.py [paths...]`` — each path is a markdown
file or a directory to scan recursively (defaults to the repo's doc roots).
Validates that every relative markdown link ``[text](target)`` resolves to
an existing file or directory; external (``http(s)://``, ``mailto:``) and
pure-anchor (``#...``) targets are skipped, anchors on relative targets are
stripped.  Exits 1 listing every broken link, so the doc tree added in this
repo (README.md, DESIGN.md, docs/, benchmarks/README.md) cannot rot
silently.  Stdlib only — runs in CI without extra deps.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
DEFAULT_PATHS = ("README.md", "DESIGN.md", "ROADMAP.md", "docs", "benchmarks")


def iter_markdown(paths):
    """Yield every markdown file under the given files/directories."""
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.md"))
        elif path.suffix == ".md" and path.exists():
            yield path


def check_file(md: Path) -> list:
    """Return (file, target) tuples for every broken relative link."""
    broken = []
    for target in LINK_RE.findall(md.read_text(encoding="utf-8")):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md.parent / rel).exists():
            broken.append((md, target))
    return broken


def main(argv) -> int:
    """CLI entrypoint; returns the process exit code."""
    paths = argv or list(DEFAULT_PATHS)
    files = list(iter_markdown(paths))
    if not files:
        print(f"check_links: no markdown files under {paths}", file=sys.stderr)
        return 1
    broken = [b for md in files for b in check_file(md)]
    for md, target in broken:
        print(f"{md}: broken relative link -> {target}", file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
