"""Quickstart: the paper's subject end to end in ~40 lines.

Builds a synthetic embedding corpus, fits three DCO methods (one per paper
category), builds an IVF index, and compares QPS / recall / pruning —
the smallest faithful slice of the benchmark.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core.engine import ScanStats, make_schedule
from repro.core.methods import make_method
from repro.search.ivf import IVFIndex
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k


def main():
    ds = load_dataset("gist", scale=0.2)          # 6k x 960 image embeddings
    print(f"dataset: {ds.name}  N={ds.n}  D={ds.dim}")
    idx = IVFIndex(n_list=64).build(ds.X)
    gt, _ = ds.ground_truth(10)
    sched = make_schedule(ds.dim)

    for name in ("FDScanning", "PDScanning+", "DDCres"):
        m = make_method(name).fit(ds.X)
        stats = ScanStats()
        found = []
        t0 = time.perf_counter()
        for qi in range(20):
            ctx = m.prep_queries(ds.Q[qi:qi + 1])      # per-query O(D^2) prep
            _, ids = idx.search(m, ctx, 0, ds.Q[qi], 10, nprobe=16,
                                schedule=sched, stats=stats)
            found.append(ids)
        qps = 20 / (time.perf_counter() - t0)
        rec = recall_at_k(np.array(found), gt[:20])
        print(f"{name:12s}  QPS={qps:7.1f}  recall@10={rec:.3f}  "
              f"dims pruned={stats.pruning_ratio:.1%}")


if __name__ == "__main__":
    main()
