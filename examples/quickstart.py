"""Quickstart: the paper's subject end to end through the facade.

Builds a synthetic embedding corpus, opens one session per DCO method
(one per paper category), and compares QPS / recall / pruning — then A/Bs
the same exact method on the host and JAX backends, which is the whole
point of the unified API.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.api import open_index
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k


def main():
    ds = load_dataset("gist", scale=0.2)          # 6k x 960 image embeddings
    print(f"dataset: {ds.name}  N={ds.n}  D={ds.dim}")
    gt, _ = ds.ground_truth(10)

    for name in ("FDScanning", "PDScanning+", "DDCres"):
        sess = open_index(ds.X, index="ivf", method=name,
                          index_params={"n_list": 64})
        res = sess.search(ds.Q[:20], 10, nprobe=16)
        rec = recall_at_k(res.ids, gt[:20])
        print(f"{name:12s}  QPS={res.qps:7.1f}  recall@10={rec:.3f}  "
              f"dims pruned={res.stats.pruning_ratio:.1%}")

    # host vs device is an A/B flag, not a second API
    for backend in ("host", "jax"):
        sess = open_index(ds.X, index="flat", method="PDScanning+",
                          backend=backend)
        sess.search(ds.Q[:20], 10)                # warm up (jit compile)
        res = sess.search(ds.Q[:20], 10)
        rec = recall_at_k(res.ids, gt[:20])
        print(f"flat/{backend:4s}    QPS={res.qps:7.1f}  recall@10={rec:.3f}")


if __name__ == "__main__":
    main()
