"""Beyond-paper: DCO-screened attention for long-context decode.

Applies the paper's two-stage dimension screening to KV-cache retrieval:
stage 1 scores all cached keys on the leading d1 PCA dims, stage 2 runs
exact attention over the top-C survivors.  Compares bytes-touched and
agreement vs exact attention across (d1, cap).

  PYTHONPATH=src python examples/dco_attention_serving.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.serving.dco_attention import (dco_decode_attention,
                                         exact_decode_attention,
                                         fit_key_rotation)


def main():
    rng = np.random.default_rng(0)
    B, S, Hkv, G, hd = 4, 4096, 4, 4, 64
    H = Hkv * G
    spec = (np.arange(1, hd + 1) ** -0.8).astype(np.float32)  # key spectrum
    k = (rng.standard_normal((B, S, Hkv, hd)) * spec).astype(np.float32)
    v = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    q = (rng.standard_normal((B, H, hd)) * spec).astype(np.float32)
    rot = jnp.asarray(fit_key_rotation(k.reshape(-1, hd)[:8192]))
    k_rot = jnp.einsum("bshd,de->bshe", jnp.asarray(k), rot)

    exact = np.asarray(exact_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                              jnp.asarray(v), S))
    full_bytes = S * hd * 4
    print(f"cache: S={S} hd={hd}  exact bytes/step/head = {full_bytes/1e6:.2f} MB")
    for d1, cap in [(8, 256), (16, 256), (16, 1024), (32, 1024)]:
        out = np.asarray(dco_decode_attention(jnp.asarray(q), k_rot,
                                              jnp.asarray(v), rot, S,
                                              d1=d1, cap=cap))
        err = np.abs(out - exact).max()
        cos = float((out * exact).sum()
                    / max(np.linalg.norm(out) * np.linalg.norm(exact), 1e-9))
        bytes_ = (S * d1 + cap * hd * 2) * 4
        print(f"d1={d1:3d} cap={cap:5d}  bytes={bytes_/1e6:5.2f} MB "
              f"({bytes_/full_bytes:5.1%})  max_err={err:.4f}  cos={cos:.3f}")


if __name__ == "__main__":
    main()
