"""Distributed retrieval serving: the paper's engine on a device mesh,
through the facade.

Opens one session with ``backend="jax"`` and a host mesh: the corpus is
sharded over the mesh, queries are batch-rotated once, and each search runs
the certified streaming engine (running-tau block scan, DESIGN.md §4) per
shard with a global top-k merge — the production serving path the dry-run
lowers against 256/512 chips, here on 8 host devices.

  PYTHONPATH=src python examples/retrieval_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.api import SchedulePolicy, open_index
from repro.launch.mesh import make_host_mesh
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k


def main():
    ds = load_dataset("sift", scale=0.3)          # 30k x 128
    mesh = make_host_mesh(4, 2)
    sess = open_index(ds.X, index="flat", method="PDScanning+", backend="jax",
                      schedule=SchedulePolicy(d1=48, capacity=2048,
                                              query_chunk=8),
                      mesh=mesh)
    res = sess.search(ds.Q[:32], 10)              # compile + run
    t0 = time.perf_counter()
    for _ in range(5):
        res = sess.search(ds.Q[:32], 10)
    dt = (time.perf_counter() - t0) / 5
    gt, _ = ds.ground_truth(10)
    rec = recall_at_k(np.asarray(res.ids), gt[:32])
    print(f"mesh={dict(mesh.shape)}  corpus={ds.n}x{ds.dim}")
    print(f"batch=32 queries in {dt*1e3:.1f} ms  ({32/dt:.0f} QPS)  "
          f"recall@10={rec:.3f} (certified streaming scan, d1=48)")


if __name__ == "__main__":
    main()
