"""Distributed retrieval serving: the paper's engine on a device mesh.

Runs the two-stage DCO engine (PDScanning+-style certified screening) over a
sharded corpus with a global top-k merge — the production serving path the
dry-run lowers against 256/512 chips, here on 8 host devices.

  PYTHONPATH=src python examples/retrieval_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.jax_engine import (DcoEngineConfig, make_distributed_topk,
                                   two_stage_topk, build_device_state)
from repro.core.methods import make_method
from repro.launch.mesh import make_host_mesh
from repro.vecdata import load_dataset
from repro.vecdata.synthetic import recall_at_k


def main():
    ds = load_dataset("sift", scale=0.3)          # 30k x 128
    m = make_method("PDScanning+").fit(ds.X)
    cfg = DcoEngineConfig(kind="lb", d1=48, k=10, capacity=2048, query_chunk=8)
    W = jnp.asarray(m.state["pca"]["W"])
    Q = jnp.asarray(ds.Q[:32]) @ W                # batched O(D^2) prep

    mesh = make_host_mesh(4, 2)
    xr = np.asarray(m.state["Xrot"], np.float32)
    sh = NamedSharding(mesh, P(("data", "model")))
    shard = lambda a: jax.device_put(a, sh)
    args = (shard(xr[:, :cfg.d1]), shard(xr[:, cfg.d1:]),
            shard((xr[:, :cfg.d1] ** 2).sum(1)),
            shard((xr[:, cfg.d1:] ** 2).sum(1)),
            Q[:, :cfg.d1], Q[:, cfg.d1:])
    fn = jax.jit(make_distributed_topk(mesh, cfg))
    d, i = fn(*args)                              # compile + run
    t0 = time.perf_counter()
    for _ in range(5):
        d, i = fn(*args)
        jax.block_until_ready(d)
    dt = (time.perf_counter() - t0) / 5
    gt, _ = ds.ground_truth(10)
    rec = recall_at_k(np.array(i), gt[:32])
    print(f"mesh={dict(mesh.shape)}  corpus={ds.n}x{ds.dim}")
    print(f"batch=32 queries in {dt*1e3:.1f} ms  ({32/dt:.0f} QPS)  "
          f"recall@10={rec:.3f} (certified two-stage, d1={cfg.d1})")


if __name__ == "__main__":
    main()
