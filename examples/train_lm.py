"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart fault tolerance (deliverable b).

Default is a short CPU-sized run; pass --steps 300 --d-model 512 for the
full ~100M-parameter exercise (slow on 1 CPU core, linear in steps).

  PYTHONPATH=src python examples/train_lm.py --steps 40
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import TokenPipeline, make_batch_fn
from repro.configs.base import RunShape
from repro.models import build_model
from repro.train.fault import StepMonitor, run_resumable
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_arch("olmo-1b").scaled(
        n_layers=args.layers, d_model=args.d_model, d_ff=4 * args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=args.d_model // 64,
        vocab=8192, vocab_pad_mult=128, head_dim=64)
    api = build_model(cfg, remat="block")
    state = init_state(api, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.name}-scaled  params={n/1e6:.1f}M  "
          f"steps={args.steps}  ckpt={args.ckpt}")

    step = jax.jit(make_train_step(api, lr_fn=lambda s: 3e-4))
    shape = RunShape("ex", args.seq, args.batch, "train")
    raw = make_batch_fn(cfg, shape)
    batch_fn = lambda s: {k: jnp.asarray(v) for k, v in raw(s).items()}

    mon = StepMonitor()
    t0 = time.perf_counter()
    state, last = run_resumable(step, state, batch_fn, steps=args.steps,
                                ckpt_dir=args.ckpt, ckpt_every=20,
                                monitor=mon)
    dt = time.perf_counter() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"done: step={last}  {tok_s:.0f} tok/s  "
          f"stragglers flagged={len(mon.stragglers)}")
    print("re-run the same command to resume from the checkpoint.")


if __name__ == "__main__":
    main()
